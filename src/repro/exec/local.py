"""Local executor: runs a planned tiled task graph and materialises the result.

Executes tasks in HEFT-priority order with a worker pool sized from the
plan's machine model (``ClusterSpec.worker_procs`` x nodes, falling back to
``os.cpu_count()`` — NumPy/BLAS releases the GIL inside GEMM, so tiles
genuinely overlap).  This is both the single-node execution path of the
framework and the correctness oracle for the scheduler: whatever HEFT
decided, the data dependencies enforced here must reproduce
``ClusteredMatrix.eager()`` exactly.

Zero-copy tile runtime:

* FILL generates **only its own tile** — INPUT tiles are views into the user
  array, RANDOM tiles come from the counter-based canonical block RNG
  (``lazy.random_slice``), ZEROS/EYE build just the tile.  No full leaf is
  ever materialised.
* CALLOC allocates in the expression dtype (``TiledProgram.dtypes``).
* Buffers are reference-counted: a tile is freed as soon as its last reader
  finishes, so peak memory is bounded by *live* tiles, not all tiles.
  ``self.stats`` records the observed peak.
* No global buffer lock: each buffer has exactly one writer at a time (the
  dependency edges guarantee it), so writes go straight into the dict;
  only the tiny refcount/scheduler bookkeeping is serialised.

``use_pallas=True`` routes ``addmul`` tiles through the Pallas blocked-GEMM
kernel (interpret mode on CPU, compiled on TPU).
"""
from __future__ import annotations

import os
import threading
from concurrent.futures import ThreadPoolExecutor
from typing import Dict, Optional

import numpy as np

from ..core.fusion import eval_fused
from ..core.graph import (Task, TaskGraph, TaskKind, TileRef,
                          matmul_epilogue, matmul_flags)
from ..core.lazy import EWISE_FNS, apply_scale, leaf_slice
from ..core.tiling import assemble, result_sets_of, tile_slices
from ..runtime.telemetry import Tracer


class LocalExecutor:
    def __init__(self, workers: Optional[int] = None, use_pallas: bool = False,
                 free_buffers: bool = True, trace: bool = True):
        self.workers = workers
        self.use_pallas = use_pallas
        self.free_buffers = free_buffers
        #: flight recorder: EXEC spans per task (node 0, one lane per
        #: pool thread); ``spans`` holds the last run's timeline
        self.trace = trace
        self.spans: list = []
        #: filled by execute(): peak_buffer_bytes, tasks_run, buffers_freed
        self.stats: Dict[str, int] = {}

    def _nworkers(self, plan) -> int:
        if self.workers:
            return self.workers
        spec = getattr(plan, "spec", None)
        if spec is not None:
            return max(1, spec.total_workers())
        return os.cpu_count() or 4

    def execute(self, plan) -> np.ndarray:
        g: TaskGraph = plan.program.graph
        tile = plan.tile
        leaf_nodes = plan.program.leaf_nodes
        dtypes = plan.program.dtypes
        residency = getattr(plan, "residency", None)
        rsets = result_sets_of(g)
        buffers: Dict[TileRef, np.ndarray] = {}

        # readers per tile buffer (+1 keeps every result tile alive for
        # final assembly, and every persisted tile alive for session
        # retention — retained output tiles are thereby excluded from
        # refcount freeing); freed at zero by the last reader
        refcnt: Dict[TileRef, int] = {}
        for t in g:
            for r in t.ins:
                refcnt[r] = refcnt.get(r, 0) + 1
        for rs in rsets:
            for r in rs.tiles:
                refcnt[r] = refcnt.get(r, 0) + 1
        mem = {"cur": 0, "peak": 0, "freed": 0}
        #: bytes currently accounted per tile ref — a task that REBINDS
        #: ``buffers[t.out]`` over an earlier allocation (ufunc output over
        #: a CALLOC'd tile, the Pallas addmul result) must release the old
        #: allocation's bytes, or ``peak_buffer_bytes`` drifts upward
        owned: Dict[TileRef, int] = {}

        if self.use_pallas:
            from ..kernels import ops as kops

        def run_task(t: Task):
            if t.kind is TaskKind.CALLOC:
                dt = dtypes.get(t.payload, np.float64)
                buffers[t.out] = np.zeros(t.out.shape, dtype=dt)
                return
            if t.kind is TaskKind.FILL:
                node = leaf_nodes[t.payload]
                rs = tile_slices(node.shape[0], tile[0])[t.out.i]
                cs = tile_slices(node.shape[1], tile[1])[t.out.j]
                buffers[t.out] = leaf_slice(node, rs[0], rs[1], cs[0], cs[1])
                return
            if t.kind is TaskKind.RESIDENT:
                # zero-copy: alias the session-resident tile into this
                # run's buffer namespace (read-only downstream)
                buffers[t.out] = residency.tile(t.payload, t.out.i, t.out.j)
                return
            if t.kind is TaskKind.ADDMUL:
                ta, tb = matmul_flags(t.payload)
                epi = matmul_epilogue(t.payload)
                a = buffers[t.ins[0]]
                b = buffers[t.ins[1]]
                a = a.T if ta else a
                b = b.T if tb else b
                c = buffers[t.out]
                if self.use_pallas:
                    if epi is not None:
                        buffers[t.out] = np.asarray(kops.addmul(
                            c, np.ascontiguousarray(a),
                            np.ascontiguousarray(b),
                            epilogue=epi,
                            extras=[np.ascontiguousarray(buffers[r])
                                    for r in t.ins[2:]]))
                    else:
                        buffers[t.out] = np.asarray(
                            kops.addmul(c, np.ascontiguousarray(a),
                                        np.ascontiguousarray(b)))
                else:
                    c += a @ b
                    if epi is not None:
                        # last task of the k-chain: apply the fused
                        # elementwise epilogue over the accumulated tile
                        buffers[t.out] = eval_fused(
                            epi, [c] + [buffers[r] for r in t.ins[2:]])
                return
            if t.kind is TaskKind.ADD:
                buffers[t.out] = buffers[t.ins[0]] + buffers[t.ins[1]]
                return
            if t.kind is TaskKind.SUB:
                buffers[t.out] = buffers[t.ins[0]] - buffers[t.ins[1]]
                return
            if t.kind is TaskKind.EWMUL:
                buffers[t.out] = buffers[t.ins[0]] * buffers[t.ins[1]]
                return
            if t.kind is TaskKind.SCALE:
                kind, s = t.payload
                buffers[t.out] = apply_scale(kind, buffers[t.ins[0]], s)
                return
            if t.kind is TaskKind.EWISE:
                buffers[t.out] = EWISE_FNS[t.payload](buffers[t.ins[0]])
                return
            if t.kind is TaskKind.FUSED:
                buffers[t.out] = eval_fused(
                    t.payload, [buffers[r] for r in t.ins])
                return
            if t.kind is TaskKind.TRANSPOSE:
                buffers[t.out] = np.ascontiguousarray(buffers[t.ins[0]].T)
                return
            if t.kind is TaskKind.TAKECOPY:
                # gather to master: locally a no-op (buffer already present)
                return
            raise ValueError(t.kind)  # pragma: no cover

        # dependency-driven execution in schedule priority order
        prio = {tid: i for i, tid in enumerate(plan.schedule.order)}
        deps_left = {t.tid: len(t.preds) for t in g}
        import heapq
        ready = [(prio[t.tid], t.tid) for t in g.sources()]
        heapq.heapify(ready)
        done_lock = threading.Lock()
        cv = threading.Condition(done_lock)
        inflight = [0]

        nworkers = self._nworkers(plan)

        def account(t: Task):
            """Memory bookkeeping after a task ran (under cv)."""
            if t.out is not None and t.kind not in (TaskKind.TAKECOPY,
                                                    TaskKind.RESIDENT):
                # RESIDENT tiles are session-owned (not this run's memory)
                buf = buffers.get(t.out)
                if buf is not None:
                    # views (zero-copy INPUT slices) own no memory
                    new = buf.nbytes if buf.base is None else 0
                    old = owned.get(t.out, 0)
                    if new != old:
                        mem["cur"] += new - old
                        if new:
                            owned[t.out] = new
                        else:
                            owned.pop(t.out, None)
                    mem["peak"] = max(mem["peak"], mem["cur"])
            if not self.free_buffers:
                return
            for r in t.ins:
                refcnt[r] -= 1
                if refcnt[r] == 0:
                    buf = buffers.pop(r, None)
                    if buf is not None:
                        mem["cur"] -= owned.pop(r, 0)
                        mem["freed"] += 1

        def worker_done(tid: int):
            with cv:
                account(g.tasks[tid])
                for s in g.tasks[tid].succs:
                    deps_left[s] -= 1
                    if deps_left[s] == 0:
                        heapq.heappush(ready, (prio[s], s))
                inflight[0] -= 1
                cv.notify_all()

        errors: list = []
        # flight recorder: one EXEC span per task on node 0, lanes keyed
        # by pool thread — the in-process equivalent of the cluster
        # workers' piggybacked spans
        tracer = Tracer(node=0, enabled=self.trace)
        with ThreadPoolExecutor(max_workers=nworkers) as pool:
            submitted = 0
            total = len(g)
            with cv:
                while submitted < total and not errors:
                    while not ready and not errors:
                        cv.wait()
                    if errors:
                        break
                    _, tid = heapq.heappop(ready)
                    inflight[0] += 1
                    submitted += 1

                    def job(tid=tid):
                        try:
                            t = g.tasks[tid]
                            with tracer.span(t.kind.name, cat="EXEC",
                                             tid=tid, kind=t.kind.name):
                                run_task(t)
                        except BaseException as e:  # surface task failures
                            errors.append(e)
                        finally:
                            worker_done(tid)

                    pool.submit(job)
                while inflight[0] > 0:
                    cv.wait()
        if errors:
            raise errors[0]

        # retention: persisted roots' tiles move to the session store.
        # Computed tiles transfer zero-copy (the run's array becomes the
        # resident tile); VIEW-backed tiles (INPUT leaf slices into the
        # user's array) are copied out — a resident handle must be a
        # snapshot that owns its memory, not an alias the caller can
        # mutate from under the session.
        retained = 0
        outs = []
        gather_bytes = 0
        for rs in rsets:
            if rs.gather:
                vals = {r: buffers[r] for r in rs.tiles}
                gather_bytes += sum(r.bytes for r in rs.tiles)
                outs.append(assemble(vals, rs.shape, tile, rs.uid))
            else:
                for r in rs.tiles:
                    buf = buffers[r]
                    if buf.base is not None:
                        buf = np.ascontiguousarray(buf)
                    residency.retain_local(rs.uid, r.i, r.j, buf)
                    retained += 1

        self.spans = tracer.drain()
        self.stats = {"peak_buffer_bytes": mem["peak"],
                      "cur_buffer_bytes": mem["cur"],
                      "buffers_freed": mem["freed"],
                      "tasks_run": len(g),
                      "workers": nworkers,
                      "gather_bytes": gather_bytes,
                      "retained_tiles": retained}
        if not outs:
            return None
        return outs[0] if len(outs) == 1 else outs
