"""Local executor: runs a planned tiled task graph and materialises the result.

Executes tasks in HEFT-priority order with a worker pool sized like the
machine model (``worker_procs`` threads — NumPy/BLAS releases the GIL inside
GEMM, so tiles genuinely overlap).  This is both the single-node execution
path of the framework and the correctness oracle for the scheduler: whatever
HEFT decided, the data dependencies enforced here must reproduce
``ClusteredMatrix.eager()`` exactly.

``use_pallas=True`` routes ``addmul`` tiles through the Pallas blocked-GEMM
kernel (interpret mode on CPU, compiled on TPU).
"""
from __future__ import annotations

import threading
from concurrent.futures import ThreadPoolExecutor
from typing import Dict, Optional

import numpy as np

from ..core.graph import Task, TaskGraph, TaskKind, TileRef
from ..core.lazy import EWISE_FNS, apply_scale, materialize_leaf
from ..core.tiling import assemble, tile_slices


class LocalExecutor:
    def __init__(self, workers: Optional[int] = None, use_pallas: bool = False):
        self.workers = workers
        self.use_pallas = use_pallas

    def execute(self, plan) -> np.ndarray:
        g: TaskGraph = plan.program.graph
        tile = plan.tile
        leaf_nodes = plan.program.leaf_nodes
        # materialised full leaves (generated once, sliced per FILL task)
        leaf_data: Dict[int, np.ndarray] = {}
        leaf_lock = threading.Lock()
        buffers: Dict[TileRef, np.ndarray] = {}
        buf_lock = threading.Lock()

        if self.use_pallas:
            from ..kernels import ops as kops

        def leaf(uid: int) -> np.ndarray:
            with leaf_lock:
                if uid not in leaf_data:
                    leaf_data[uid] = materialize_leaf(leaf_nodes[uid])
                return leaf_data[uid]

        def run_task(t: Task):
            if t.kind is TaskKind.CALLOC:
                with buf_lock:
                    buffers[t.out] = np.zeros(t.out.shape)
                return
            if t.kind is TaskKind.FILL:
                full = leaf(t.payload)
                rs = tile_slices(full.shape[0], tile[0])[t.out.i]
                cs = tile_slices(full.shape[1], tile[1])[t.out.j]
                val = np.ascontiguousarray(full[rs[0]:rs[1], cs[0]:cs[1]])
                with buf_lock:
                    buffers[t.out] = val
                return
            if t.kind is TaskKind.ADDMUL:
                a = buffers[t.ins[0]]
                b = buffers[t.ins[1]]
                c = buffers[t.out]
                if self.use_pallas:
                    buffers[t.out] = np.asarray(kops.addmul(c, a, b))
                else:
                    c += a @ b
                return
            if t.kind is TaskKind.ADD:
                buffers[t.out] = buffers[t.ins[0]] + buffers[t.ins[1]]
                return
            if t.kind is TaskKind.SUB:
                buffers[t.out] = buffers[t.ins[0]] - buffers[t.ins[1]]
                return
            if t.kind is TaskKind.EWMUL:
                buffers[t.out] = buffers[t.ins[0]] * buffers[t.ins[1]]
                return
            if t.kind is TaskKind.SCALE:
                kind, s = t.payload
                buffers[t.out] = apply_scale(kind, buffers[t.ins[0]], s)
                return
            if t.kind is TaskKind.EWISE:
                buffers[t.out] = EWISE_FNS[t.payload](buffers[t.ins[0]])
                return
            if t.kind is TaskKind.TRANSPOSE:
                buffers[t.out] = np.ascontiguousarray(buffers[t.ins[0]].T)
                return
            if t.kind is TaskKind.TAKECOPY:
                # gather to master: locally a no-op (buffer already present)
                return
            raise ValueError(t.kind)  # pragma: no cover

        # dependency-driven execution in schedule priority order
        prio = {tid: i for i, tid in enumerate(plan.schedule.order)}
        deps_left = {t.tid: len(t.preds) for t in g}
        import heapq
        ready = [(prio[t.tid], t.tid) for t in g.sources()]
        heapq.heapify(ready)
        done_lock = threading.Lock()
        cv = threading.Condition(done_lock)
        inflight = [0]

        nworkers = self.workers or 4

        def worker_done(tid: int):
            with cv:
                for s in g.tasks[tid].succs:
                    deps_left[s] -= 1
                    if deps_left[s] == 0:
                        heapq.heappush(ready, (prio[s], s))
                inflight[0] -= 1
                cv.notify_all()

        with ThreadPoolExecutor(max_workers=nworkers) as pool:
            submitted = 0
            total = len(g)
            with cv:
                while submitted < total:
                    while not ready:
                        cv.wait()
                    _, tid = heapq.heappop(ready)
                    inflight[0] += 1
                    submitted += 1

                    def job(tid=tid):
                        try:
                            run_task(g.tasks[tid])
                        finally:
                            worker_done(tid)

                    pool.submit(job)
                while inflight[0] > 0:
                    cv.wait()

        vals = {r: buffers[r] for r in g.result_tiles}
        return assemble(vals, g.result_shape, tile,
                        g.result_tiles[0].tensor)
