"""Multi-process cluster executor: HEFT node placements run for real.

The paper's claim is that CMM "automatically configures communication and
worker processes" so the schedule produced for a multi-node cluster
actually executes across nodes.  The in-process executors
(``exec/local.py``, ``exec/batched.py``) validate *numerics* but collapse
the cluster to one address space and ignore the schedule's node
assignments.  This backend closes the loop:

* one **worker process per ClusterSpec node** (numpywren-style isolated
  workers over shared storage), each running ``spec.workers_at(node)``
  compute threads — heterogeneous specs (unequal worker counts/speeds per
  node) spawn unequal pools, so ``plan()``'s placement decisions are
  exercised, not just simulated;
* every task runs **on the process of its HEFT-assigned node**
  (``Schedule.placements``), driven by per-node dispatch queues;
* tile buffers live in per-node ``multiprocessing.SharedMemory`` **tile
  arenas** (one segment per live tile buffer, owned by the node that
  produced it);
* cross-node dependency edges become **XFER** operations: the consumer
  node attaches the producer node's segment and copies the tile into its
  own arena — a real inter-process copy, overlapped with compute (XFERs
  run on the node's thread pool while other tiles execute);
* one XFER per tile *version* per destination node — later consumers on
  that node reuse the arrived copy, mirroring the §3.5 node-level cache
  the scheduler planned with;
* segments are **reference-counted** exactly like ``exec/local.py``'s
  owned-bytes accounting: the master tracks static per-(node, tile) reader
  counts (task inputs + accumulate-chain holds + outgoing XFER reads +
  result-gather holds) and tells the owning node to free a segment as soon
  as its last reader finishes.

Numerics: every task executes the same NumPy calls as ``LocalExecutor``
and tile movement is bit-copying, so results are **bit-identical** to the
per-task executor (asserted across the paper suite in
``tests/test_cmm_suite.py``).  The Pallas tile kernel is not routed
through this backend.

``predict_cluster_makespan`` is the executor-strategy leg for ``"auto"``:
it re-simulates the schedule under the profiler-calibrated process
dispatch + IPC terms (``TimeModel.process_dispatch_overhead`` /
``ipc_bandwidth`` / ``ipc_latency``, see ``profiler.calibrate_ipc``) so
the engine can weigh the multi-process strategy against the in-process
ones per plan.
"""
from __future__ import annotations

import itertools
import os
import queue as _queue
import threading
import time
import traceback
import zlib
from collections import defaultdict
from concurrent.futures import ThreadPoolExecutor
from dataclasses import replace
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..core.fusion import eval_fused
from ..core.graph import TaskGraph, TaskKind, TileRef, matmul_flags
from ..core.heft import Schedule, edge_bytes
from ..core.lazy import EWISE_FNS, Op, apply_scale, leaf_slice
from ..core.machine import ClusterSpec
from ..core.timemodel import TimeModel
from ..core.tiling import assemble, tile_slices

#: task kinds that accumulate into their output tile in place (the chain
#: holds the buffer alive without listing it in ``ins`` — same bookkeeping
#: as the wave executor's slab refcounts)
_CHAIN_KINDS = (TaskKind.ADDMUL, TaskKind.MATMUL)


#: serialises SharedMemory create/attach so the attach-time tracker patch
#: below can never swallow a concurrent create's registration
_TRACK_LOCK = threading.Lock()


def _attach_shm(name: str):
    """Attach an existing segment WITHOUT registering it with the resource
    tracker (bpo-39959: attaches register too, but the tracker's cache is a
    set — the owner's create+unlink pair then unbalances and the tracker
    raises KeyError / warns about leaks at shutdown).  Only the creating
    node registers a segment; crash cleanup still covers every segment."""
    from multiprocessing import resource_tracker, shared_memory
    with _TRACK_LOCK:
        orig = resource_tracker.register
        resource_tracker.register = lambda *a, **kw: None
        try:
            return shared_memory.SharedMemory(name=name)
        finally:
            resource_tracker.register = orig


def _release_seg(seg, unlink: bool = True) -> None:
    """Close (+unlink) tolerating live views: a reader thread that grabbed
    the ndarray before a rebind keeps the mapping alive until it drops the
    reference; unlinking just removes the name."""
    try:
        seg.close()
    except BufferError:
        pass
    if unlink:
        try:
            seg.unlink()
        except FileNotFoundError:       # pragma: no cover
            pass


class _NodeArena:
    """One node's shared-memory tile arena: a segment per live buffer,
    with exec/local.py-style owned-bytes accounting.

    Session residency adds two orthogonal states to a binding:

    * **retained** — a segment moved out of the per-run ref namespace into
      the session store (keyed by ``(handle id, i, j)``); it survives
      end-of-run freeing and later runs, until the session drops it;
    * **alias** — a ref bound onto a retained segment by a RESIDENT task
      (zero-copy re-entry).  Freeing or rebinding an alias drops only the
      binding, never the underlying retained segment.
    """

    def __init__(self, prefix: str, node: int):
        self._lock = threading.Lock()
        self._segs: Dict[TileRef, object] = {}
        self._arrs: Dict[TileRef, np.ndarray] = {}
        #: session-retained segments: (hid, i, j) -> (seg, arr)
        self._retained: Dict[Tuple[int, int, int], Tuple[object, object]] = {}
        #: refs whose binding aliases a retained segment (not owned)
        self._alias: set = set()
        self._count = itertools.count()
        self._prefix = f"{prefix}n{node}"
        self.cur = 0
        self.peak = 0
        self.freed = 0
        self.allocs = 0
        self.retained_bytes = 0

    def _new_seg(self, nbytes: int):
        from multiprocessing import shared_memory
        with _TRACK_LOCK:
            return shared_memory.SharedMemory(
                create=True, size=max(int(nbytes), 1),
                name=f"{self._prefix}_{next(self._count)}")

    def alloc(self, ref: TileRef, shape, dtype) -> np.ndarray:
        """A fresh zeroed buffer for ``ref`` (CALLOC — shm is zero-filled
        by the OS, matching ``np.zeros``)."""
        dtype = np.dtype(dtype)
        n = int(np.prod(shape)) * dtype.itemsize
        seg = self._new_seg(n)
        arr = np.ndarray(shape, dtype=dtype, buffer=seg.buf)
        arr[...] = 0
        self._adopt(ref, seg, arr)
        return arr

    def store(self, ref: TileRef, value: np.ndarray) -> np.ndarray:
        """Copy ``value`` into a new segment bound to ``ref``."""
        value = np.asarray(value)
        seg = self._new_seg(value.nbytes)
        arr = np.ndarray(value.shape, dtype=value.dtype, buffer=seg.buf)
        arr[...] = value
        self._adopt(ref, seg, arr)
        return arr

    def _adopt(self, ref: TileRef, seg, arr: np.ndarray) -> None:
        with self._lock:
            # replace in place — ``get`` is lock-free, so the key must
            # never be absent during a rebind (a reader racing a
            # duplicate-producer rebind sees the old or new buffer, both
            # holding the same tile value)
            old = self._segs.get(ref)
            was_alias = ref in self._alias
            self._alias.discard(ref)
            self._segs[ref] = seg
            self._arrs[ref] = arr
            if old is not None and not was_alias:
                # rebind over a superseded version: release the old
                # allocation's bytes (the exec/local.py drift fix).
                # An alias binding owned neither bytes nor the segment.
                self.cur -= old.size
                self.freed += 1
                _release_seg(old)
            self.allocs += 1
            self.cur += seg.size
            self.peak = max(self.peak, self.cur)

    def get(self, ref: TileRef) -> np.ndarray:
        return self._arrs[ref]

    def seg_of(self, ref: TileRef) -> Tuple[str, str]:
        with self._lock:
            return self._segs[ref].name, self._arrs[ref].dtype.str

    def free(self, ref: TileRef) -> None:
        with self._lock:
            seg = self._segs.pop(ref, None)
            self._arrs.pop(ref, None)
            if ref in self._alias:
                # alias of a retained segment: drop the binding only
                self._alias.discard(ref)
                return
            if seg is not None:
                self.cur -= seg.size
                self.freed += 1
                _release_seg(seg)

    # -- session residency ---------------------------------------------------
    def retain(self, key: Tuple[int, int, int], ref: TileRef) -> None:
        """Move ``ref``'s segment into the retained (session) store under
        ``key`` — it leaves this run's byte accounting and survives until
        ``drop_retained``.  An alias binding (persist of an expression that
        folded to a resident leaf) is deep-copied so every retained key
        owns its segment exclusively."""
        with self._lock:
            seg = self._segs.pop(ref, None)
            arr = self._arrs.pop(ref, None)
            if seg is None:
                raise KeyError(f"retain of unbound ref {ref}")
            if ref in self._alias:
                self._alias.discard(ref)
                src = arr
                seg = self._new_seg(src.nbytes)
                arr = np.ndarray(src.shape, dtype=src.dtype, buffer=seg.buf)
                arr[...] = src
            else:
                self.cur -= seg.size
            old = self._retained.get(key)
            if old is not None:         # re-retention under the same key
                self.retained_bytes -= old[0].size
                _release_seg(old[0])
            self._retained[key] = (seg, arr)
            self.retained_bytes += seg.size

    def bind_retained(self, ref: TileRef,
                      key: Tuple[int, int, int]) -> None:
        """Alias ``ref`` onto a retained segment (RESIDENT task): zero-copy
        re-entry of a session tile into this run's namespace."""
        with self._lock:
            ent = self._retained.get(key)
            if ent is None:
                raise KeyError(f"no retained tile {key} in this arena "
                               f"(resident tile lost?)")
            old = self._segs.get(ref)
            if old is not None and ref not in self._alias:
                self.cur -= old.size
                self.freed += 1
                _release_seg(old)
            self._segs[ref], self._arrs[ref] = ent
            self._alias.add(ref)

    def drop_retained(self, key: Tuple[int, int, int]) -> None:
        with self._lock:
            ent = self._retained.pop(key, None)
            if ent is not None:
                self.retained_bytes -= ent[0].size
                _release_seg(ent[0])

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {"peak_buffer_bytes": self.peak,
                    "cur_buffer_bytes": self.cur,
                    "buffers_freed": self.freed,
                    "buffers_alloc": self.allocs,
                    "live_buffers": len(self._segs),
                    "retained": len(self._retained),
                    "retained_bytes": self.retained_bytes}

    def destroy(self) -> None:
        with self._lock:
            for ref, seg in self._segs.items():
                if ref not in self._alias:
                    _release_seg(seg)
            self._segs.clear()
            self._arrs.clear()
            self._alias.clear()
            for (seg, _arr) in self._retained.values():
                _release_seg(seg)
            self._retained.clear()


def _execute_task(t, arena: _NodeArena, leaf_nodes, dtypes,
                  tile, resident_ids=None
                  ) -> Tuple[Optional[str], Optional[str]]:
    """Run one task against the node arena; mirrors the per-task executor's
    kernels exactly (bit-identity contract).  Returns the output buffer's
    (segment name, dtype str)."""
    k = t.kind
    if k is TaskKind.CALLOC:
        arena.alloc(t.out, t.out.shape, dtypes.get(t.payload, np.float64))
        return arena.seg_of(t.out)
    if k is TaskKind.TAKECOPY:
        # gather to master: the tile already sits in the master node's
        # arena (produced here or XFER'd in) — nothing to compute
        return arena.seg_of(t.out)
    if k is TaskKind.RESIDENT:
        # session-resident tile: alias the retained segment into this
        # run's ref namespace (zero-copy, this node is the tile's home)
        hid = (resident_ids or {})[t.payload]
        arena.bind_retained(t.out, (hid, t.out.i, t.out.j))
        return arena.seg_of(t.out)
    if k in _CHAIN_KINDS:
        ta, tb = matmul_flags(t.payload)
        a = arena.get(t.ins[0])
        b = arena.get(t.ins[1])
        a = a.T if ta else a
        b = b.T if tb else b
        c = arena.get(t.out)
        c += a @ b
        return arena.seg_of(t.out)
    if k is TaskKind.FILL:
        node = leaf_nodes[t.payload]
        rs = tile_slices(node.shape[0], tile[0])[t.out.i]
        cs = tile_slices(node.shape[1], tile[1])[t.out.j]
        val = leaf_slice(node, rs[0], rs[1], cs[0], cs[1])
    elif k is TaskKind.ADD:
        val = arena.get(t.ins[0]) + arena.get(t.ins[1])
    elif k is TaskKind.SUB:
        val = arena.get(t.ins[0]) - arena.get(t.ins[1])
    elif k is TaskKind.EWMUL:
        val = arena.get(t.ins[0]) * arena.get(t.ins[1])
    elif k is TaskKind.SCALE:
        kind, s = t.payload
        val = apply_scale(kind, arena.get(t.ins[0]), s)
    elif k is TaskKind.EWISE:
        val = EWISE_FNS[t.payload](arena.get(t.ins[0]))
    elif k is TaskKind.FUSED:
        val = eval_fused(t.payload, [arena.get(r) for r in t.ins])
    elif k is TaskKind.TRANSPOSE:
        val = np.ascontiguousarray(arena.get(t.ins[0]).T)
    else:       # pragma: no cover
        raise ValueError(t.kind)
    arena.store(t.out, val)
    return arena.seg_of(t.out)


def _node_worker(node: int, inq, outq, g: TaskGraph, tile, leaf_nodes,
                 dtypes, nthreads: int, prefix: str,
                 hb_interval: float = 0.0,
                 blas_threads: Optional[int] = None) -> None:
    """One cluster node: a dispatch-queue loop feeding a thread pool of
    ``nthreads`` compute slots, with tiles in this node's shm arena.
    XFER copies run on the same pool, so they overlap in-flight compute.

    ``hb_interval > 0`` enables the elastic runtime's liveness protocol:
    the worker emits ``("hb", node, pid)`` whenever the dispatch queue is
    idle for that long, reports per-task service time in its ``done``
    messages (straggler EWMA input), and honours a ``("throttle", s)``
    op (fault-injection: make this node artificially slow).  XFER
    failures are reported as recoverable ``xfer_fail`` events — a dead
    source node's segment vanishing mid-copy must re-route, not crash.

    ``blas_threads`` caps this process's BLAS pool (the machine model's
    ``threads_per_worker``): without it one worker process can saturate
    every host core through OpenBLAS threading, which hides the
    process-level scaling the cluster model is about.

    Session mode spawns the worker with ``g=None`` and ships the run
    context (graph, tile, leaves, dtypes, resident-leaf handle ids) per
    run via a ``("run", ...)`` op — the process and its arena (with the
    session's retained tiles) survive across runs.
    """
    if blas_threads:
        try:
            import threadpoolctl
            threadpoolctl.threadpool_limits(blas_threads)
        except ImportError:             # pragma: no cover
            pass
    arena = _NodeArena(prefix, node)
    pid = os.getpid()
    throttle = [0.0]
    ctx = {"g": g, "tile": tile, "leaf_nodes": leaf_nodes,
           "dtypes": dtypes, "resident_ids": {}}

    def run_task(tid: int) -> None:
        try:
            t0 = time.perf_counter()
            if throttle[0] > 0.0:
                time.sleep(throttle[0])
            seg, dt = _execute_task(ctx["g"].tasks[tid], arena,
                                    ctx["leaf_nodes"], ctx["dtypes"],
                                    ctx["tile"], ctx["resident_ids"])
            outq.put(("done", node, tid, seg, dt, pid,
                      time.perf_counter() - t0))
        except BaseException:
            outq.put(("error", node, tid, traceback.format_exc()))

    def run_xfer(version: int, ref: TileRef, src_name: str,
                 dtype_str: str) -> None:
        try:
            remote = _attach_shm(src_name)
            try:
                src = np.ndarray(ref.shape, dtype=np.dtype(dtype_str),
                                 buffer=remote.buf)
                # CRC32 over the payload before and after the copy: a
                # source segment vanishing or being rebound mid-copy (a
                # torn read) lands here as a recoverable xfer_fail — the
                # elastic master retries from a live holder — instead of
                # silently propagating wrong bytes
                want = zlib.crc32(src.data) & 0xFFFFFFFF
                copied = arena.store(ref, src)
                got = zlib.crc32(copied.data) & 0xFFFFFFFF
                if got != want:
                    raise RuntimeError(
                        f"XFER payload CRC32 mismatch for {ref}: copied "
                        f"{got:#010x} != source {want:#010x}")
            finally:
                remote.close()
            seg, dt = arena.seg_of(ref)
            outq.put(("xfer_done", node, version, ref, seg, dt))
        except BaseException:
            outq.put(("xfer_fail", node, version, ref,
                      traceback.format_exc()))

    with ThreadPoolExecutor(max_workers=max(1, nthreads)) as pool:
        while True:
            if hb_interval > 0.0:
                try:
                    msg = inq.get(timeout=hb_interval)
                except _queue.Empty:
                    outq.put(("hb", node, pid))
                    continue
            else:
                msg = inq.get()
            op = msg[0]
            if op == "task":
                pool.submit(run_task, msg[1])
            elif op == "xfer":
                pool.submit(run_xfer, msg[1], msg[2], msg[3], msg[4])
            elif op == "free":
                arena.free(msg[1])
            elif op == "run":
                # session mode: (re)bind this worker to a new run's
                # graph/leaves — the arena (incl. retained tiles) persists
                ctx["g"], ctx["tile"] = msg[1], msg[2]
                ctx["leaf_nodes"], ctx["dtypes"] = msg[3], msg[4]
                ctx["resident_ids"] = msg[5]
            elif op == "retain":
                # move a persisted output tile into the session store
                try:
                    arena.retain(msg[2], msg[1])
                except BaseException:
                    outq.put(("error", node, -1, traceback.format_exc()))
            elif op == "drop":
                arena.drop_retained(msg[1])
            elif op == "audit":
                outq.put(("audit", node, arena.stats()))
            elif op == "throttle":
                throttle[0] = float(msg[1])
            elif op == "stop":
                break
    stats = arena.stats()
    arena.destroy()
    outq.put(("stats", node, stats, pid))


class ClusterExecutor:
    """Executes a planned tiled program across one process per cluster node,
    honoring the HEFT schedule's per-task node placement.

    ``workers_per_node`` overrides the per-node thread count (default:
    ``spec.workers_at(node)``); ``free_buffers=False`` keeps every segment
    alive until shutdown; ``mp_context`` picks the multiprocessing start
    method (default ``fork`` where available — workers inherit the plan
    instead of re-pickling it); ``timeout`` bounds each wait on worker
    events so a dead worker raises instead of hanging.

    ``session=True`` turns this into a session backend: the worker
    processes (and their arenas, holding the session's retained tiles)
    are spawned on the first ``execute()`` and SURVIVE across runs — each
    run ships its graph to the workers via a ``("run", ...)`` op.
    ``close_session()`` shuts the workers down and returns a per-node
    arena audit (live/retained buffer counts for the session's refcount
    audit).
    """

    def __init__(self, workers_per_node: Optional[int] = None,
                 free_buffers: bool = True,
                 mp_context: Optional[str] = None,
                 timeout: float = 300.0,
                 session: bool = False):
        self.workers_per_node = workers_per_node
        self.free_buffers = free_buffers
        self.mp_context = mp_context
        self.timeout = timeout
        self.session = session
        self.stats: Dict[str, object] = {}
        self._procs: Optional[List] = None
        self._inqs: Optional[List] = None
        self._outq = None
        self._spec: Optional[ClusterSpec] = None
        self._prefix = ""
        self._broken = False

    # -- driver --------------------------------------------------------------
    def execute(self, plan) -> np.ndarray:
        import multiprocessing as mp

        g: TaskGraph = plan.program.graph
        spec: Optional[ClusterSpec] = getattr(plan, "spec", None)
        if spec is None:
            raise ValueError("ClusterExecutor needs plan.spec "
                             "(a ClusterSpec) to spawn node processes")
        residency = getattr(plan, "residency", None)
        from ..core.tiling import result_sets_of
        rsets = result_sets_of(g)
        if self.session and self._broken:
            raise RuntimeError("session cluster executor is broken "
                               "(a previous run failed); open a new session")
        if self.session and self._spec is not None and self._spec != spec:
            raise ValueError("a session cluster executor is bound to one "
                             "ClusterSpec; plan was made for a different "
                             "spec")
        sched: Schedule = plan.schedule
        node_of = {tid: p.node for tid, p in sched.placements.items()}
        missing = [tid for tid in g.tasks if tid not in node_of]
        if missing:
            raise ValueError(f"schedule places {len(node_of)} tasks but the "
                             f"graph has {len(g.tasks)}; unplaced: "
                             f"{missing[:5]}")

        method = self.mp_context or (
            "fork" if "fork" in mp.get_all_start_methods() else "spawn")
        ctx = mp.get_context(method)
        prefix = f"cmm{os.getpid()}_{next(_RUN_IDS)}_"

        # -- static dataflow: XFER endpoints, waiters, reader counts --------
        xfer_by_producer: Dict[int, List[Tuple[int, int]]] = defaultdict(list)
        for (p, _src, dst, nbytes) in sched.xfers(g):
            xfer_by_producer[p].append((dst, nbytes))
        waiters: Dict[Tuple[int, int], List[int]] = defaultdict(list)
        xfers_left: Dict[int, int] = defaultdict(int)
        reads: Dict[Tuple[int, TileRef], int] = defaultdict(int)
        for t in g:
            n = node_of[t.tid]
            for r in t.ins:
                reads[(n, r)] += 1
            if t.kind in _CHAIN_KINDS and t.out is not None:
                reads[(n, t.out)] += 1
            for p in t.preds:
                if node_of[p] != n and edge_bytes(g, g.tasks[p], t) > 0:
                    waiters[(p, n)].append(t.tid)
                    xfers_left[t.tid] += 1
        for p, dsts in xfer_by_producer.items():
            reads[(node_of[p], g.tasks[p].out)] += len(dsts)
        master_node = spec.master
        # gather holds for takecopy'd roots; retention holds pin each
        # persisted tile on its final producer's node so end-of-run
        # refcount freeing can never free a tile the session retains
        retained_refs: Dict[TileRef, Tuple[int, int]] = {}
        for rs in rsets:
            if rs.gather:
                for r in rs.tiles:
                    reads[(master_node, r)] += 1
            else:
                for r in rs.tiles:
                    home = node_of[rs.producers[r]]
                    reads[(home, r)] += 1
                    retained_refs[r] = (rs.uid, home)

        # -- spawn one worker process per node (session: reuse) -------------
        if self.session and self._procs is not None:
            outq, inqs, procs = self._outq, self._inqs, self._procs
            prefix = self._prefix
        else:
            outq = ctx.Queue()
            inqs = [ctx.Queue() for _ in range(spec.n_nodes)]
            procs = []
            for n in range(spec.n_nodes):
                nthreads = self.workers_per_node or spec.workers_at(n)
                args = (n, inqs[n], outq, None, None, None, None,
                        nthreads, prefix) if self.session else \
                    (n, inqs[n], outq, g, plan.tile,
                     plan.program.leaf_nodes, plan.program.dtypes,
                     nthreads, prefix)
                p = ctx.Process(target=_node_worker, args=args, daemon=True)
                p.start()
                procs.append(p)
            if self.session:
                self._procs, self._inqs, self._outq = procs, inqs, outq
                self._spec, self._prefix = spec, prefix
        if self.session:
            # ship this run's context; RESIDENT leaves are resolved worker-
            # side via their handle ids (the handles stay master-side)
            worker_leafs = {uid: n for uid, n in
                            plan.program.leaf_nodes.items()
                            if n.op is not Op.RESIDENT}
            rids = residency.resident_ids() if residency is not None else {}
            run_msg = ("run", g, plan.tile, worker_leafs,
                       plan.program.dtypes, rids)
            for q in inqs:
                q.put(run_msg)

        seg_info: Dict[Tuple[int, TileRef], Tuple[str, str]] = {}
        exec_nodes: Dict[int, int] = {}
        node_pids: Dict[int, int] = {}
        deps_left = {t.tid: len(t.preds) for t in g}
        dispatched = set()
        counters = {"xfers": 0, "xfer_bytes": 0}

        def dec_read(n: int, r: TileRef) -> None:
            if not self.free_buffers:
                return
            key = (n, r)
            c = reads.get(key)
            if c is None:
                return
            if c <= 1:
                del reads[key]
                inqs[n].put(("free", r))
            else:
                reads[key] = c - 1

        def maybe_dispatch(tid: int) -> None:
            if tid in dispatched:
                return
            if deps_left[tid] == 0 and xfers_left[tid] == 0:
                dispatched.add(tid)
                inqs[node_of[tid]].put(("task", tid))

        def next_event():
            deadline = time.monotonic() + self.timeout
            while True:
                wait = min(10.0, max(0.1, deadline - time.monotonic()))
                try:
                    return outq.get(timeout=wait)
                except _queue.Empty:
                    dead = [i for i, p in enumerate(procs)
                            if not p.is_alive()]
                    if dead:
                        raise RuntimeError(
                            f"cluster worker process(es) {dead} died "
                            f"(exit codes "
                            f"{[procs[i].exitcode for i in dead]})")
                    if time.monotonic() >= deadline:
                        raise RuntimeError(
                            f"cluster execution stalled: no worker event "
                            f"within timeout={self.timeout}s")

        total = len(g)
        done = 0
        try:
            for t in g.sources():
                maybe_dispatch(t.tid)
            while done < total:
                msg = next_event()
                kind = msg[0]
                if kind == "done":
                    _, n, tid, seg, dt, pid, _dur = msg
                    t = g.tasks[tid]
                    if seg is not None and t.out is not None:
                        seg_info[(n, t.out)] = (seg, dt)
                    exec_nodes[tid] = n
                    node_pids[n] = pid
                    done += 1
                    for (dst, nbytes) in xfer_by_producer.get(tid, ()):
                        sname, sdt = seg_info[(n, t.out)]
                        inqs[dst].put(("xfer", tid, t.out, sname, sdt))
                        counters["xfers"] += 1
                        counters["xfer_bytes"] += nbytes
                    for s in sorted(t.succs):
                        deps_left[s] -= 1
                        maybe_dispatch(s)
                    for r in t.ins:
                        dec_read(n, r)
                    if t.kind in _CHAIN_KINDS and t.out is not None:
                        dec_read(n, t.out)
                elif kind == "xfer_done":
                    _, n, version, ref, seg, dt = msg
                    seg_info[(n, ref)] = (seg, dt)
                    dec_read(node_of[version], g.tasks[version].out)
                    for s in waiters.pop((version, n), ()):
                        xfers_left[s] -= 1
                        maybe_dispatch(s)
                elif kind == "error":
                    raise RuntimeError(
                        f"cluster task failed on node {msg[1]} "
                        f"(task {msg[2]}):\n{msg[3]}")
                elif kind == "xfer_fail":
                    # static membership: an XFER can only fail if the run
                    # is already broken — no re-route target exists
                    raise RuntimeError(
                        f"cluster XFER of {msg[3]} (version {msg[2]}) "
                        f"failed on node {msg[1]}:\n{msg[4]}")

            # -- gather result tiles from the master node's arena ----------
            outs: List[np.ndarray] = []
            gather_bytes = 0
            retained = 0
            for rs in rsets:
                if not rs.gather:
                    continue
                vals: Dict[TileRef, np.ndarray] = {}
                for r in rs.tiles:
                    sname, dt = seg_info[(master_node, r)]
                    seg = _attach_shm(sname)
                    try:
                        view = np.ndarray(r.shape, dtype=np.dtype(dt),
                                          buffer=seg.buf)
                        vals[r] = view.copy()
                    finally:
                        seg.close()
                    gather_bytes += r.bytes
                    dec_read(master_node, r)
                outs.append(assemble(vals, rs.shape, plan.tile, rs.uid))

            # -- retention: persisted tiles move to the session store -------
            for r, (uid, home) in retained_refs.items():
                sname, dt = seg_info[(home, r)]
                h = residency.retain[uid]
                inqs[home].put(("retain", r, (h.hid, r.i, r.j)))
                residency.retain_seg(uid, r.i, r.j, home, sname, dt)
                retained += 1

            # -- orderly shutdown + per-node stats --------------------------
            node_stats: Dict[int, Dict[str, int]] = {}
            if self.session:
                # workers survive; audit instead of stop (the audit reply
                # also confirms every retain op above was processed)
                for q in inqs:
                    q.put(("audit",))
                while len(node_stats) < spec.n_nodes:
                    msg = next_event()
                    if msg[0] == "audit":
                        node_stats[msg[1]] = msg[2]
                    elif msg[0] == "error":     # pragma: no cover
                        raise RuntimeError(f"cluster worker error during "
                                           f"retention:\n{msg[3]}")
            else:
                for q in inqs:
                    q.put(("stop",))
                while len(node_stats) < spec.n_nodes:
                    msg = next_event()
                    if msg[0] == "stats":
                        node_stats[msg[1]] = msg[2]
                        node_pids.setdefault(msg[1], msg[3])
                    elif msg[0] == "error":     # pragma: no cover
                        raise RuntimeError(f"cluster worker error during "
                                           f"shutdown:\n{msg[3]}")
                for p in procs:
                    p.join(timeout=self.timeout)
        except BaseException:
            self._broken = True
            for p in procs:
                if p.is_alive():
                    p.terminate()
            # best-effort unlink of segments the (terminated) workers own;
            # tracker register/unregister are silenced — these names were
            # registered by the workers' trackers, not the master's
            from multiprocessing import resource_tracker, shared_memory
            with _TRACK_LOCK:
                orig = (resource_tracker.register,
                        resource_tracker.unregister)
                resource_tracker.register = lambda *a, **kw: None
                resource_tracker.unregister = lambda *a, **kw: None
                try:
                    names = {sname for (sname, _dt) in seg_info.values()}
                    if os.path.isdir("/dev/shm"):
                        # segments allocated but not yet reported when the
                        # workers were terminated are only findable by the
                        # run's namespace prefix
                        names.update(f for f in os.listdir("/dev/shm")
                                     if f.startswith(prefix))
                    for sname in names:
                        try:
                            _release_seg(
                                shared_memory.SharedMemory(name=sname))
                        except FileNotFoundError:
                            pass
                finally:
                    (resource_tracker.register,
                     resource_tracker.unregister) = orig
            raise
        finally:
            if not self.session or self._broken:
                for p in procs:
                    if p.is_alive():        # pragma: no cover
                        p.terminate()
                        p.join(timeout=5)

        self.stats = {
            "tasks_run": total,
            "workers": sum(self.workers_per_node or spec.workers_at(n)
                           for n in range(spec.n_nodes)),
            "nodes": spec.n_nodes,
            "xfers": counters["xfers"],
            "xfer_bytes": counters["xfer_bytes"],
            "gather_bytes": gather_bytes,
            "retained_tiles": retained,
            "peak_buffer_bytes": sum(s["peak_buffer_bytes"]
                                     for s in node_stats.values()),
            "cur_buffer_bytes": sum(s["cur_buffer_bytes"]
                                    for s in node_stats.values()),
            "buffers_freed": sum(s["buffers_freed"]
                                 for s in node_stats.values()),
            "live_buffers": sum(s.get("live_buffers", 0)
                                for s in node_stats.values()),
            "retained_total": sum(s.get("retained", 0)
                                  for s in node_stats.values()),
            "exec_nodes": exec_nodes,
            "node_pids": node_pids,
        }
        if not outs:
            return None
        return outs[0] if len(outs) == 1 else outs

    # -- session lifecycle ----------------------------------------------------
    def drop_retained(self, node: int, key) -> None:
        """Session free path: drop one retained tile from ``node``'s arena."""
        if self._inqs is not None and not self._broken:
            self._inqs[node].put(("drop", key))

    def close_session(self) -> Dict[int, Dict[str, int]]:
        """Stop the long-lived workers; returns the per-node arena stats
        collected at shutdown (live/retained buffer counts — the session's
        refcount audit input)."""
        audit: Dict[int, Dict[str, int]] = {}
        if self._procs is None:
            return audit
        if not self._broken:
            for q in self._inqs:
                q.put(("stop",))
            deadline = time.monotonic() + min(self.timeout, 30.0)
            while len(audit) < len(self._procs) and \
                    time.monotonic() < deadline:
                try:
                    msg = self._outq.get(timeout=0.5)
                except _queue.Empty:
                    if all(not p.is_alive() for p in self._procs):
                        break
                    continue
                if msg[0] == "stats":
                    audit[msg[1]] = msg[2]
        for p in self._procs:
            p.join(timeout=5)
            if p.is_alive():                     # pragma: no cover
                p.terminate()
        self._procs = self._inqs = self._outq = None
        return audit


#: unique per-execute() shm namespace within this master process
_RUN_IDS = itertools.count()


def predict_cluster_makespan(g: TaskGraph, sched: Schedule,
                             spec: ClusterSpec, tm: TimeModel) -> float:
    """Predicted wall-clock of the multi-process cluster executor.

    Re-simulates the schedule with the machine model swapped to what this
    backend actually pays: per-task process dispatch
    (``tm.process_dispatch_overhead``) and shared-memory XFER transfers
    (``tm.ipc_latency + bytes / tm.ipc_bandwidth``) instead of the network
    link model.  The engine compares this against the per-task and
    wave-batched predictions to pick ``executor="auto"``'s strategy.
    """
    from ..core.simulator import simulate
    ipc_spec = replace(spec, link_bw=max(tm.ipc_bandwidth, 1.0),
                       latency=max(tm.ipc_latency, 0.0), pair_bw=())
    tm_proc = replace(tm, dispatch_overhead=tm.process_dispatch_overhead)
    return simulate(g, sched, ipc_spec, tm_proc).makespan
