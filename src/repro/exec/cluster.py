"""Multi-process cluster executor: HEFT node placements run for real.

The paper's claim is that CMM "automatically configures communication and
worker processes" so the schedule produced for a multi-node cluster
actually executes across nodes.  The in-process executors
(``exec/local.py``, ``exec/batched.py``) validate *numerics* but collapse
the cluster to one address space and ignore the schedule's node
assignments.  This backend closes the loop:

* one **worker process per ClusterSpec node** (numpywren-style isolated
  workers over shared storage), each running ``spec.workers_at(node)``
  compute threads — heterogeneous specs (unequal worker counts/speeds per
  node) spawn unequal pools, so ``plan()``'s placement decisions are
  exercised, not just simulated;
* every task runs **on the process of its HEFT-assigned node**
  (``Schedule.placements``), driven by per-node dispatch queues;
* tile buffers live in per-node ``multiprocessing.SharedMemory`` **tile
  arenas** (one segment per live tile buffer, owned by the node that
  produced it);
* cross-node dependency edges become **XFER** operations: the consumer
  node attaches the producer node's segment and copies the tile into its
  own arena — a real inter-process copy, overlapped with compute (XFERs
  run on the node's thread pool while other tiles execute);
* one XFER per tile *version* per destination node — later consumers on
  that node reuse the arrived copy, mirroring the §3.5 node-level cache
  the scheduler planned with;
* segments are **reference-counted** exactly like ``exec/local.py``'s
  owned-bytes accounting: the master tracks static per-(node, tile) reader
  counts (task inputs + accumulate-chain holds + outgoing XFER reads +
  result-gather holds) and tells the owning node to free a segment as soon
  as its last reader finishes.

Numerics: every task executes the same NumPy calls as ``LocalExecutor``
and tile movement is bit-copying, so results are **bit-identical** to the
per-task executor (asserted across the paper suite in
``tests/test_cmm_suite.py``).  The Pallas tile kernel is not routed
through this backend.

``predict_cluster_makespan`` is the executor-strategy leg for ``"auto"``:
it re-simulates the schedule under the profiler-calibrated process
dispatch + IPC terms (``TimeModel.process_dispatch_overhead`` /
``ipc_bandwidth`` / ``ipc_latency``, see ``profiler.calibrate_ipc``) so
the engine can weigh the multi-process strategy against the in-process
ones per plan.
"""
from __future__ import annotations

import itertools
import os
import queue as _queue
import shutil
import threading
import time
import traceback
import zlib
from collections import defaultdict
from concurrent.futures import ThreadPoolExecutor
from dataclasses import replace
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..core.fusion import eval_fused
from ..core.graph import (TaskGraph, TaskKind, TileRef, matmul_epilogue,
                          matmul_flags)
from ..core.heft import Schedule, edge_bytes
from ..core.lazy import EWISE_FNS, Op, apply_scale, leaf_slice
from ..core.machine import ClusterSpec, MemoryBudgetExceeded
from ..core.timemodel import TimeModel

from ..core.tiling import assemble, tile_slices
from ..runtime.spill import (AllocFailInjected, ArenaOverflow, SpillCorrupt,
                             SpillDataLost, SpillMiss, TileSpillStore,
                             run_spill_dir)
from ..runtime.telemetry import (MetricsRegistry, Span, Tracer,
                                 estimate_clock_offset)
from ..runtime.wire import (BCAST_MIN_FANOUT, broadcast_tree,
                            choose_wire_codec, decode_tile, encode_tile)

#: chain-of-custody CRC audit (debug aid): when set, workers stamp a
#: CRC32 on every tile custody transfer (task done, spill, unspill, XFER)
#: and the master cross-checks each hop, printing the first corrupt stage
_CRCAUDIT = bool(os.environ.get("CMM_CRCAUDIT"))

#: task kinds that accumulate into their output tile in place (the chain
#: holds the buffer alive without listing it in ``ins`` — same bookkeeping
#: as the wave executor's slab refcounts)
_CHAIN_KINDS = (TaskKind.ADDMUL, TaskKind.MATMUL)


#: serialises SharedMemory create/attach so the attach-time tracker patch
#: below can never swallow a concurrent create's registration
_TRACK_LOCK = threading.Lock()


def _attach_shm(name: str):
    """Attach an existing segment WITHOUT registering it with the resource
    tracker (bpo-39959: attaches register too, but the tracker's cache is a
    set — the owner's create+unlink pair then unbalances and the tracker
    raises KeyError / warns about leaks at shutdown).  Only the creating
    node registers a segment; crash cleanup still covers every segment."""
    from multiprocessing import resource_tracker, shared_memory
    with _TRACK_LOCK:
        orig = resource_tracker.register
        resource_tracker.register = lambda *a, **kw: None
        try:
            return shared_memory.SharedMemory(name=name)
        finally:
            resource_tracker.register = orig


def _release_seg(seg, unlink: bool = True) -> None:
    """Close (+unlink) a segment.  ``close()`` unmaps the memory even when
    ndarray views over ``seg.buf`` are still alive — a subsequent read
    through such a view hits unmapped (or, worse, remapped-to-another-
    segment) pages.  Callers must guarantee no live reader exists (the
    arena's pin protocol) or defer the close via ``_NodeArena._limbo``."""
    try:
        seg.close()
    except BufferError:                 # pragma: no cover
        pass
    if unlink:
        try:
            seg.unlink()
        except FileNotFoundError:       # pragma: no cover
            pass


class _NodeArena:
    """One node's shared-memory tile arena: a segment per live buffer,
    with exec/local.py-style owned-bytes accounting.

    Session residency adds two orthogonal states to a binding:

    * **retained** — a segment moved out of the per-run ref namespace into
      the session store (keyed by ``(handle id, i, j)``); it survives
      end-of-run freeing and later runs, until the session drops it;
    * **alias** — a ref bound onto a retained segment by a RESIDENT task
      (zero-copy re-entry).  Freeing or rebinding an alias drops only the
      binding, never the underlying retained segment.
    """

    def __init__(self, prefix: str, node: int,
                 mem_bytes: Optional[int] = None,
                 spill_dir: Optional[str] = None,
                 on_spill=None, on_unspill=None):
        # reentrant: budgeted allocation (_new_seg -> _ensure -> _evict)
        # happens both outside and inside lock-holding paths (retain)
        self._lock = threading.RLock()
        self._segs: Dict[TileRef, object] = {}
        #: ref -> ndarray in LRU order (oldest first); touched by get/adopt
        self._arrs: Dict[TileRef, np.ndarray] = {}
        #: session-retained segments: (hid, i, j) -> (seg, arr)
        self._retained: Dict[Tuple[int, int, int], Tuple[object, object]] = {}
        #: refs whose binding aliases a retained segment (not owned)
        self._alias: set = set()
        #: in-flight pin refcounts: a pinned ref is never evicted (task
        #: inputs/outputs for the duration of the task, XFER destinations)
        self._pinned: Dict[TileRef, int] = {}
        #: frees that arrived while the ref was pinned — honoured at the
        #: last unpin.  Releasing a segment unmaps it even under live
        #: ndarray views (close() invalidates them), so freeing a pinned
        #: ref would hand its in-flight reader unmapped (or worse,
        #: remapped-to-another-tile) pages.
        self._free_pending: set = set()
        #: superseded segments whose name is already unlinked but whose
        #: mapping may still back a pinned reader's view; closed when the
        #: arena is quiescent (no pins)
        self._limbo: list = []
        self._count = itertools.count()
        self._prefix = f"{prefix}n{node}"
        #: byte budget for cur + retained; None = unbounded (legacy mode)
        self.budget = None if mem_bytes is None else int(mem_bytes)
        self._spill_dir = spill_dir
        self._spill: Optional[TileSpillStore] = None
        self._on_spill = on_spill
        self._on_unspill = on_unspill
        #: chaos: fail the Nth fresh allocation (-1 = disarmed)
        self._alloc_fail_after = -1
        self.cur = 0
        self.peak = 0
        self.freed = 0
        self.allocs = 0
        self.retained_bytes = 0
        self.evictions = 0
        self.faults = 0
        #: flight-recorder hook: the worker sets this once at startup so
        #: the lazily-created spill store records SPILL/FAULTIN spans
        self.tracer = None

    def _store(self) -> TileSpillStore:
        if self._spill is None:
            d = self._spill_dir or run_spill_dir(self._prefix)
            self._spill = TileSpillStore(d, self._prefix)
            self._spill.tracer = self.tracer
        return self._spill

    def _evictable(self) -> Optional[TileRef]:
        """Coldest unpinned non-alias ref, or None (LRU = dict order)."""
        for ref in self._arrs:
            if ref in self._alias or self._pinned.get(ref):
                continue
            return ref
        return None

    def _evict(self, ref: TileRef) -> None:
        """Move ``ref``'s tile to the spill tier (lock held).  The spill
        write completes before the segment is released, and existing
        mappings (a reader that already ``get``-ed the array) stay valid
        until dropped — eviction changes where bytes live, never values."""
        seg = self._segs.pop(ref)
        arr = self._arrs.pop(ref)
        crc = (zlib.crc32(np.ascontiguousarray(arr).data) & 0xFFFFFFFF
               if _CRCAUDIT else None)
        self._store().spill(ref, arr)
        self.cur -= seg.size
        self.evictions += 1
        del arr
        _release_seg(seg)
        if self._on_spill is not None:
            self._on_spill(ref, crc)

    def _ensure(self, nbytes: int, strict: bool = True) -> None:
        """Evict cold tiles until ``nbytes`` more fit the budget (lock
        held).  ``strict`` raises ArenaOverflow when nothing evictable
        remains; non-strict (mid-run squeeze) evicts best-effort."""
        if self.budget is None:
            return
        while self.cur + self.retained_bytes + nbytes > self.budget:
            victim = self._evictable()
            if victim is None:
                if strict:
                    raise ArenaOverflow(
                        f"arena {self._prefix}: need {nbytes} bytes but "
                        f"{self.cur} allocated + {self.retained_bytes} "
                        f"retained of budget {self.budget} are pinned or "
                        f"retained — nothing left to evict")
                return
            self._evict(victim)

    def _maybe_inject_alloc_fail(self) -> None:
        with self._lock:
            if self._alloc_fail_after > 0:
                self._alloc_fail_after -= 1
                if self._alloc_fail_after == 0:
                    self._alloc_fail_after = -1
                    raise AllocFailInjected(
                        f"arena {self._prefix}: chaos-injected allocation "
                        f"failure")

    def _new_seg(self, nbytes: int):
        from multiprocessing import shared_memory
        with self._lock:
            self._ensure(int(nbytes))
            with _TRACK_LOCK:
                seg = shared_memory.SharedMemory(
                    create=True, size=max(int(nbytes), 1),
                    name=f"{self._prefix}_{next(self._count)}")
            # pre-charge so concurrent allocations see the reservation
            # before the (lock-free) copy completes and _adopt binds it
            self.cur += seg.size
            self.peak = max(self.peak, self.cur)
            return seg

    def alloc(self, ref: TileRef, shape, dtype) -> np.ndarray:
        """A fresh zeroed buffer for ``ref`` (CALLOC — shm is zero-filled
        by the OS, matching ``np.zeros``)."""
        self._maybe_inject_alloc_fail()
        dtype = np.dtype(dtype)
        n = int(np.prod(shape)) * dtype.itemsize
        seg = self._new_seg(n)
        arr = np.ndarray(shape, dtype=dtype, buffer=seg.buf)
        arr[...] = 0
        self._adopt(ref, seg, arr)
        return arr

    def store(self, ref: TileRef, value: np.ndarray) -> np.ndarray:
        """Copy ``value`` into a new segment bound to ``ref``."""
        self._maybe_inject_alloc_fail()
        value = np.asarray(value)
        seg = self._new_seg(value.nbytes)
        arr = np.ndarray(value.shape, dtype=value.dtype, buffer=seg.buf)
        arr[...] = value
        self._adopt(ref, seg, arr)
        return arr

    def _adopt(self, ref: TileRef, seg, arr: np.ndarray) -> None:
        with self._lock:
            # replace in place — the unbounded ``get`` fast path is
            # lock-free, so the key must never be absent during a rebind
            # (a reader racing a duplicate-producer rebind sees the old or
            # new buffer, both holding the same tile value)
            old = self._segs.get(ref)
            was_alias = ref in self._alias
            self._alias.discard(ref)
            self._segs[ref] = seg
            self._arrs.pop(ref, None)       # rebind lands at the LRU tail
            self._arrs[ref] = arr
            if self._spill is not None:
                # a spilled older version is superseded by this rebind
                self._spill.drop(ref)
            if old is not None and not was_alias:
                # rebind over a superseded version: release the old
                # allocation's bytes (the exec/local.py drift fix).
                # An alias binding owned neither bytes nor the segment.
                self.cur -= old.size
                self.freed += 1
                if self._pinned.get(ref):
                    # a pinned reader may still map the superseded
                    # segment: unlink the name now, close only once the
                    # arena is quiescent (close unmaps under live views)
                    try:
                        old.unlink()
                    except FileNotFoundError:   # pragma: no cover
                        pass
                    self._limbo.append(old)
                else:
                    _release_seg(old)
            self.allocs += 1
            # bytes were pre-charged by _new_seg

    def _fault_in(self, ref: TileRef) -> np.ndarray:
        """Reload a spilled tile into a fresh segment (lock held).  A
        missing or corrupt spill file surfaces as SpillDataLost carrying
        the ref, so the master can degrade to lineage recompute.  The
        disk entry is dropped only after the hot binding exists — if
        ``_new_seg`` overflows, the sole copy stays on disk for the
        retry."""
        try:
            data = self._store().fault_in(ref, keep=True)
        except (SpillMiss, SpillCorrupt) as e:
            self._store().drop(ref)
            raise SpillDataLost(ref, str(e))
        seg = self._new_seg(data.nbytes)    # may evict other cold tiles
        arr = np.ndarray(data.shape, dtype=data.dtype, buffer=seg.buf)
        arr[...] = data
        self._segs[ref] = seg
        self._arrs[ref] = arr
        self._store().drop(ref)
        self.allocs += 1
        self.faults += 1
        if self._on_unspill is not None:
            crc = (zlib.crc32(arr.data) & 0xFFFFFFFF
                   if _CRCAUDIT else None)
            self._on_unspill(ref, seg.name, arr.dtype.str, crc)
        return arr

    def get(self, ref: TileRef) -> np.ndarray:
        if self.budget is None:
            return self._arrs[ref]          # unbounded: lock-free fast path
        with self._lock:
            arr = self._arrs.get(ref)
            if arr is not None:
                self._arrs[ref] = self._arrs.pop(ref)      # LRU touch
                return arr
            if self._spill is not None and ref in self._spill:
                return self._fault_in(ref)
            raise KeyError(ref)

    def pin_all(self, refs) -> None:
        """Exempt ``refs`` from eviction while a task/XFER uses them."""
        with self._lock:
            for r in refs:
                self._pinned[r] = self._pinned.get(r, 0) + 1

    def unpin_all(self, refs) -> None:
        with self._lock:
            for r in refs:
                n = self._pinned.get(r, 0) - 1
                if n <= 0:
                    self._pinned.pop(r, None)
                    if r in self._free_pending:
                        # the master freed this ref mid-flight; honour it
                        # now that no reader maps its buffer (reentrant)
                        self._free_pending.discard(r)
                        self.free(r)
                else:
                    self._pinned[r] = n
            if not self._pinned and self._limbo:
                for seg in self._limbo:
                    _release_seg(seg, unlink=False)
                self._limbo.clear()

    def set_budget(self, nbytes: Optional[int]) -> None:
        """Shrink (or lift) the byte budget mid-run (``mem_squeeze``
        chaos / elastic re-admission); evicts down best-effort."""
        with self._lock:
            self.budget = None if nbytes is None else int(nbytes)
            self._ensure(0, strict=False)

    def arm_alloc_fail(self, nth: int) -> None:
        with self._lock:
            self._alloc_fail_after = max(1, int(nth))

    def seg_of(self, ref: TileRef) -> Tuple[str, str]:
        with self._lock:
            if ref not in self._segs and self._spill is not None \
                    and ref in self._spill:
                self._fault_in(ref)
            return self._segs[ref].name, self._arrs[ref].dtype.str

    def free(self, ref: TileRef) -> None:
        with self._lock:
            if self._pinned.get(ref):
                # an in-flight task/XFER still reads this buffer: defer
                # the release to its last unpin (see _free_pending)
                self._free_pending.add(ref)
                return
            self._free_pending.discard(ref)
            if self._spill is not None and ref in self._spill:
                # freeing a spilled ref: drop the cold copy
                self._spill.drop(ref)
                self.freed += 1
                return
            seg = self._segs.pop(ref, None)
            self._arrs.pop(ref, None)
            if ref in self._alias:
                # alias of a retained segment: drop the binding only
                self._alias.discard(ref)
                return
            if seg is not None:
                self.cur -= seg.size
                self.freed += 1
                _release_seg(seg)

    # -- session residency ---------------------------------------------------
    def retain(self, key: Tuple[int, int, int], ref: TileRef) -> None:
        """Move ``ref``'s segment into the retained (session) store under
        ``key`` — it leaves this run's byte accounting and survives until
        ``drop_retained``.  An alias binding (persist of an expression that
        folded to a resident leaf) is deep-copied so every retained key
        owns its segment exclusively."""
        with self._lock:
            if ref not in self._segs and self._spill is not None \
                    and ref in self._spill:
                self._fault_in(ref)     # retained tiles live in the hot tier
            seg = self._segs.pop(ref, None)
            arr = self._arrs.pop(ref, None)
            if seg is None:
                raise KeyError(f"retain of unbound ref {ref}")
            if ref in self._alias:
                self._alias.discard(ref)
                src = arr
                seg = self._new_seg(src.nbytes)
                arr = np.ndarray(src.shape, dtype=src.dtype, buffer=seg.buf)
                arr[...] = src
            self.cur -= seg.size        # moves to the retained accounting
            old = self._retained.get(key)
            if old is not None:         # re-retention under the same key
                self.retained_bytes -= old[0].size
                _release_seg(old[0])
            self._retained[key] = (seg, arr)
            self.retained_bytes += seg.size

    def bind_retained(self, ref: TileRef,
                      key: Tuple[int, int, int]) -> None:
        """Alias ``ref`` onto a retained segment (RESIDENT task): zero-copy
        re-entry of a session tile into this run's namespace."""
        with self._lock:
            ent = self._retained.get(key)
            if ent is None:
                raise KeyError(f"no retained tile {key} in this arena "
                               f"(resident tile lost?)")
            old = self._segs.get(ref)
            if old is not None and ref not in self._alias:
                self.cur -= old.size
                self.freed += 1
                _release_seg(old)
            self._segs[ref], self._arrs[ref] = ent
            self._alias.add(ref)

    def drop_retained(self, key: Tuple[int, int, int]) -> None:
        with self._lock:
            ent = self._retained.pop(key, None)
            if ent is not None:
                self.retained_bytes -= ent[0].size
                _release_seg(ent[0])

    def retained_seg(self, key: Tuple[int, int, int]) -> Tuple[str, str]:
        """Authoritative (segment name, dtype) of a retained tile — the
        retain-ack payload (a retain may have faulted the tile in first,
        renaming its segment, so the master must not trust a stale name)."""
        with self._lock:
            seg, arr = self._retained[key]
            return seg.name, arr.dtype.str

    def stats(self) -> Dict[str, int]:
        with self._lock:
            sp = self._spill
            return {"peak_buffer_bytes": self.peak,
                    "cur_buffer_bytes": self.cur,
                    "buffers_freed": self.freed,
                    "buffers_alloc": self.allocs,
                    "live_buffers": len(self._segs),
                    "retained": len(self._retained),
                    "retained_bytes": self.retained_bytes,
                    "mem_budget": 0 if self.budget is None else self.budget,
                    "evictions": self.evictions,
                    "faults": self.faults,
                    "spill_writes": 0 if sp is None else sp.writes,
                    "spill_reads": 0 if sp is None else sp.reads,
                    "spill_files": 0 if sp is None else sp.live_files,
                    "spilled_bytes": 0 if sp is None else sp.live_bytes}

    def destroy(self) -> None:
        with self._lock:
            for ref, seg in self._segs.items():
                if ref not in self._alias:
                    _release_seg(seg)
            self._segs.clear()
            self._arrs.clear()
            self._alias.clear()
            self._pinned.clear()
            self._free_pending.clear()
            for seg in self._limbo:
                _release_seg(seg, unlink=False)
            self._limbo.clear()
            for (seg, _arr) in self._retained.values():
                _release_seg(seg)
            self._retained.clear()
            if self._spill is not None:
                self._spill.destroy()


def _execute_task(t, arena: _NodeArena, leaf_nodes, dtypes,
                  tile, resident_ids=None
                  ) -> Tuple[Optional[str], Optional[str]]:
    """Run one task against the node arena; mirrors the per-task executor's
    kernels exactly (bit-identity contract).  Returns the output buffer's
    (segment name, dtype str)."""
    k = t.kind
    if k is TaskKind.CALLOC:
        arena.alloc(t.out, t.out.shape, dtypes.get(t.payload, np.float64))
        return arena.seg_of(t.out)
    if k is TaskKind.TAKECOPY:
        # gather to master: the tile already sits in the master node's
        # arena (produced here or XFER'd in) — nothing to compute
        return arena.seg_of(t.out)
    if k is TaskKind.RESIDENT:
        # session-resident tile: alias the retained segment into this
        # run's ref namespace (zero-copy, this node is the tile's home)
        hid = (resident_ids or {})[t.payload]
        arena.bind_retained(t.out, (hid, t.out.i, t.out.j))
        return arena.seg_of(t.out)
    if k in _CHAIN_KINDS:
        ta, tb = matmul_flags(t.payload)
        a = arena.get(t.ins[0])
        b = arena.get(t.ins[1])
        a = a.T if ta else a
        b = b.T if tb else b
        c = arena.get(t.out)
        c += a @ b
        epi = matmul_epilogue(t.payload)
        if epi is not None:
            # last task of the k-chain: fused elementwise epilogue over
            # the accumulated tile (rebinds the output segment — store
            # runs before seg_of so the master sees the new segment)
            arena.store(t.out, eval_fused(
                epi, [c] + [arena.get(r) for r in t.ins[2:]]))
        return arena.seg_of(t.out)
    if k is TaskKind.FILL:
        node = leaf_nodes[t.payload]
        rs = tile_slices(node.shape[0], tile[0])[t.out.i]
        cs = tile_slices(node.shape[1], tile[1])[t.out.j]
        val = leaf_slice(node, rs[0], rs[1], cs[0], cs[1])
    elif k is TaskKind.ADD:
        val = arena.get(t.ins[0]) + arena.get(t.ins[1])
    elif k is TaskKind.SUB:
        val = arena.get(t.ins[0]) - arena.get(t.ins[1])
    elif k is TaskKind.EWMUL:
        val = arena.get(t.ins[0]) * arena.get(t.ins[1])
    elif k is TaskKind.SCALE:
        kind, s = t.payload
        val = apply_scale(kind, arena.get(t.ins[0]), s)
    elif k is TaskKind.EWISE:
        val = EWISE_FNS[t.payload](arena.get(t.ins[0]))
    elif k is TaskKind.FUSED:
        val = eval_fused(t.payload, [arena.get(r) for r in t.ins])
    elif k is TaskKind.TRANSPOSE:
        val = np.ascontiguousarray(arena.get(t.ins[0]).T)
    else:       # pragma: no cover
        raise ValueError(t.kind)
    arena.store(t.out, val)
    return arena.seg_of(t.out)


def _node_worker(node: int, inq, outq, g: TaskGraph, tile, leaf_nodes,
                 dtypes, nthreads: int, prefix: str,
                 hb_interval: float = 0.0,
                 blas_threads: Optional[int] = None,
                 mem_bytes: Optional[int] = None,
                 spill_dir: Optional[str] = None,
                 trace: bool = True) -> None:
    """One cluster node: a dispatch-queue loop feeding a thread pool of
    ``nthreads`` compute slots, with tiles in this node's shm arena.
    XFER copies run on the same pool, so they overlap in-flight compute.

    ``hb_interval > 0`` enables the elastic runtime's liveness protocol:
    the worker emits ``("hb", node, pid)`` whenever the dispatch queue is
    idle for that long, reports per-task service time in its ``done``
    messages (straggler EWMA input), and honours a ``("throttle", s)``
    op (fault-injection: make this node artificially slow).  XFER
    failures are reported as recoverable ``xfer_fail`` events — a dead
    source node's segment vanishing mid-copy must re-route, not crash.

    ``blas_threads`` caps this process's BLAS pool (the machine model's
    ``threads_per_worker``): without it one worker process can saturate
    every host core through OpenBLAS threading, which hides the
    process-level scaling the cluster model is about.

    Session mode spawns the worker with ``g=None`` and ships the run
    context (graph, tile, leaves, dtypes, resident-leaf handle ids) per
    run via a ``("run", ...)`` op — the process and its arena (with the
    session's retained tiles) survive across runs.

    ``mem_bytes`` bounds the arena (``ClusterSpec.mem_at``): on pressure
    cold unpinned tiles spill to ``spill_dir`` and fault back in on read.
    Every eviction posts ``("spill", node, ref)`` and every fault-in posts
    ``("unspill", node, ref, segname, dtype)`` so the master's segment-name
    maps track where tiles live; a lost spill file posts
    ``("tile_lost", node, ref, tb)`` for lineage recompute.

    A bounded arena also serves XFER/gather *leases*: ``("hold", ref)``
    pins the tile (faulting it hot if cold) and acks ``("held", node,
    ref, segname, dtype, crc)``; ``("release", ref)`` drops the pin.
    Without the lease, a reader attaching the acked segment name races
    eviction — under pressure the LRU can cycle the whole arena inside
    the master→consumer round trip, so name-based retries livelock.

    The compressed wire path generalises the lease: ``("pack", ref,
    codec)`` pins the tile AND stages its encoded payload in a transient
    wire segment (outside the arena budget), acking ``("packed", node,
    ref, segname, dtype, codec, comp_nbytes, raw_crc)``; the consumer
    attaches the staging segment, decodes, and CRC-checks the *decoded*
    bytes against ``raw_crc`` — bit-identity end to end.  ``("unpack",
    ref)`` drops one pack lease; the staging segment is destroyed when
    the last lease on it drops.
    """
    if blas_threads:
        try:
            import threadpoolctl
            threadpoolctl.threadpool_limits(blas_threads)
        except ImportError:             # pragma: no cover
            pass

    def _on_spill(ref: TileRef, crc=None) -> None:
        outq.put(("spill", node, ref, crc))

    def _on_unspill(ref: TileRef, segname: str, dtype_str: str,
                    crc=None) -> None:
        outq.put(("unspill", node, ref, segname, dtype_str, crc))

    arena = _NodeArena(prefix, node, mem_bytes=mem_bytes,
                       spill_dir=spill_dir,
                       on_spill=_on_spill, on_unspill=_on_unspill)
    #: flight recorder: spans buffer here and piggyback on the done /
    #: xfer_done / hb / stats messages already flowing to the master —
    #: tracing adds no queue traffic of its own
    tracer = Tracer(node=node, enabled=trace)
    arena.tracer = tracer
    pid = os.getpid()
    throttle = [0.0]
    #: refs the master released this run — a ("fault", ref) op that pool-
    #: schedules AFTER the inline ("free", ref) is obsolete, not a lost tile
    freed_refs: set = set()
    ctx = {"g": g, "tile": tile, "leaf_nodes": leaf_nodes,
           "dtypes": dtypes, "resident_ids": {}}

    def run_task(tid: int) -> None:
        t = ctx["g"].tasks[tid]
        # pin the working set: in-flight inputs and the (possibly mutated
        # in place) output must stay in the hot tier for the task's whole
        # duration — eviction mid-mutation would spill a partial value
        pins = list(t.ins) + ([t.out] if t.out is not None else [])
        arena.pin_all(pins)
        try:
            t0 = time.perf_counter()
            with tracer.span(t.kind.name, cat="EXEC", tid=tid,
                             kind=t.kind.name):
                if throttle[0] > 0.0:
                    time.sleep(throttle[0])
                seg, dt = _execute_task(t, arena,
                                        ctx["leaf_nodes"], ctx["dtypes"],
                                        ctx["tile"], ctx["resident_ids"])
            crc = None
            if _CRCAUDIT and t.out is not None:
                crc = zlib.crc32(arena.get(t.out).data) & 0xFFFFFFFF
            outq.put(("done", node, tid, seg, dt, pid,
                      time.perf_counter() - t0, crc, tracer.drain()))
        except BaseException as e:
            if isinstance(e, SpillDataLost):
                # the master must drop this holding BEFORE retrying the
                # task (per-worker FIFO guarantees the ordering)
                outq.put(("tile_lost", node, e.ref, traceback.format_exc()))
            outq.put(("error", node, tid, traceback.format_exc()))
        finally:
            arena.unpin_all(pins)

    def run_xfer(version: int, ref: TileRef, src_name: str,
                 dtype_str: str, codec: str = "raw",
                 comp_nbytes: int = 0, raw_crc=None) -> None:
        arena.pin_all((ref,))
        try:
            nbytes = (int(np.prod(ref.shape))
                      * np.dtype(dtype_str).itemsize)
            with tracer.span("XFER", nbytes=nbytes, codec=codec,
                             comp_nbytes=comp_nbytes, version=version):
                if throttle[0] > 0.0:
                    # a slow node is slow at moving bytes too (straggler
                    # modelling; also gives chaos tests a deterministic
                    # in-flight window)
                    time.sleep(throttle[0])
                remote = _attach_shm(src_name)
                try:
                    if codec != "raw":
                        # compressed wire path: the staging segment holds
                        # the encoded payload; decode locally and verify
                        # the CRC of the *decoded* bytes against the
                        # source's stamp — torn reads and codec faults
                        # both land as recoverable xfer_fail, never as
                        # wrong bytes
                        payload = bytes(remote.buf[:comp_nbytes])
                        src = decode_tile(payload, ref.shape,
                                          np.dtype(dtype_str), codec)
                        want = zlib.crc32(src.data) & 0xFFFFFFFF
                        if raw_crc is not None and want != raw_crc:
                            raise RuntimeError(
                                f"XFER decoded-payload CRC32 mismatch for "
                                f"{ref}: {want:#010x} != {raw_crc:#010x}")
                    else:
                        src = np.ndarray(ref.shape,
                                         dtype=np.dtype(dtype_str),
                                         buffer=remote.buf)
                        # CRC32 over the payload before and after the
                        # copy: a source segment vanishing or being
                        # rebound mid-copy (a torn read) lands here as a
                        # recoverable xfer_fail — the elastic master
                        # retries from a live holder — instead of
                        # silently propagating wrong bytes
                        want = zlib.crc32(src.data) & 0xFFFFFFFF
                    copied = arena.store(ref, src)
                    got = zlib.crc32(copied.data) & 0xFFFFFFFF
                    if got != want:
                        raise RuntimeError(
                            f"XFER payload CRC32 mismatch for {ref}: "
                            f"copied {got:#010x} != source {want:#010x}")
                finally:
                    remote.close()
            seg, dt = arena.seg_of(ref)
            outq.put(("xfer_done", node, version, ref, seg, dt,
                      got if _CRCAUDIT else None, tracer.drain()))
        except BaseException:
            outq.put(("xfer_fail", node, version, ref,
                      traceback.format_exc()))
        finally:
            arena.unpin_all((ref,))

    #: ref -> [staging seg, lease count, codec, comp_nbytes, raw_crc,
    #: dtype_str] — wire payloads staged for outgoing compressed XFERs.
    #: Transient buffers outside the arena budget; each "pack" lease
    #: also pins the source tile, so the staged bytes stay authoritative.
    packs: Dict[TileRef, list] = {}
    pack_ids = itertools.count()

    def run_pack(ref: TileRef, codec: str) -> None:
        from multiprocessing import shared_memory
        arena.pin_all((ref,))
        try:
            ent = packs.get(ref)
            if ent is None:
                arr = arena.get(ref)     # faults the tile hot if cold
                with tracer.span("PACK", nbytes=int(arr.nbytes),
                                 codec=codec) as psp:
                    payload = encode_tile(arr, codec)
                    raw_crc = zlib.crc32(np.ascontiguousarray(arr).data) \
                        & 0xFFFFFFFF
                    with _TRACK_LOCK:
                        seg = shared_memory.SharedMemory(
                            create=True, size=max(len(payload), 1),
                            name=f"{prefix}w{node}_{next(pack_ids)}")
                    seg.buf[:len(payload)] = payload
                    if tracer.enabled:
                        psp.args["comp_nbytes"] = len(payload)
                ent = packs[ref] = [seg, 0, codec, len(payload), raw_crc,
                                    arr.dtype.str]
            ent[1] += 1
            outq.put(("packed", node, ref, ent[0].name, ent[5], ent[2],
                      ent[3], ent[4]))
        except KeyError:
            arena.unpin_all((ref,))
            if ref not in freed_refs:
                outq.put(("tile_lost", node, ref, traceback.format_exc()))
        except SpillDataLost:
            arena.unpin_all((ref,))
            outq.put(("tile_lost", node, ref, traceback.format_exc()))
        except ArenaOverflow:
            # transient, like "hold": the master re-sends (bounded)
            arena.unpin_all((ref,))
            outq.put(("hold_fail", node, ref))
        except BaseException:
            arena.unpin_all((ref,))
            outq.put(("error", node, -1, traceback.format_exc()))

    def drop_pack(ref: TileRef) -> None:
        ent = packs.get(ref)
        if ent is None:                 # pragma: no cover - defensive
            return
        ent[1] -= 1
        arena.unpin_all((ref,))
        if ent[1] <= 0:
            _release_seg(ent[0])
            del packs[ref]

    def run_fault(ref: TileRef) -> None:
        """Master-requested fault-in of a spilled tile (it wants to XFER
        from or gather this node).  Always acks with the current segment
        name — the tile may have been faulted back in locally already."""
        arena.pin_all((ref,))
        try:
            arr = arena.get(ref)
            seg, dt = arena.seg_of(ref)
            crc = (zlib.crc32(arr.data) & 0xFFFFFFFF
                   if _CRCAUDIT else None)
            outq.put(("unspill", node, ref, seg, dt, crc))
        except KeyError:
            if ref in freed_refs:
                # the master freed this ref after requesting the fault
                # (its last reader finished first); the request is stale
                return
            outq.put(("tile_lost", node, ref, traceback.format_exc()))
        except SpillDataLost:
            outq.put(("tile_lost", node, ref, traceback.format_exc()))
        except BaseException:
            outq.put(("error", node, -1, traceback.format_exc()))
        finally:
            arena.unpin_all((ref,))

    with ThreadPoolExecutor(max_workers=max(1, nthreads)) as pool:
        while True:
            if hb_interval > 0.0:
                try:
                    msg = inq.get(timeout=hb_interval)
                except _queue.Empty:
                    outq.put(("hb", node, pid, tracer.drain()))
                    continue
            else:
                msg = inq.get()
            op = msg[0]
            if op == "task":
                pool.submit(run_task, msg[1])
            elif op == "xfer":
                pool.submit(run_xfer, msg[1], msg[2], msg[3], msg[4],
                            *msg[5:])
            elif op == "free":
                freed_refs.add(msg[1])
                arena.free(msg[1])
            elif op == "hold":
                # lease this tile as an XFER/gather source: pin it so
                # neither eviction nor a rebind can invalidate the acked
                # segment name before the consumer attaches (under
                # pressure the LRU can cycle the whole arena in the
                # master->consumer round-trip window, livelocking the
                # name-based retry).  The pin is released by "release"
                # once the copy lands.
                ref = msg[1]
                arena.pin_all((ref,))
                try:
                    arr = arena.get(ref)    # faults the tile hot if cold
                    seg, dt = arena.seg_of(ref)
                    crc = (zlib.crc32(arr.data) & 0xFFFFFFFF
                           if _CRCAUDIT else None)
                    outq.put(("held", node, ref, seg, dt, crc))
                except KeyError:
                    arena.unpin_all((ref,))
                    if ref not in freed_refs:
                        outq.put(("tile_lost", node, ref,
                                  traceback.format_exc()))
                except SpillDataLost:
                    arena.unpin_all((ref,))
                    outq.put(("tile_lost", node, ref,
                              traceback.format_exc()))
                except ArenaOverflow:
                    # transient: concurrent tasks' pins drain as they
                    # finish — the master re-sends the hold (bounded)
                    arena.unpin_all((ref,))
                    outq.put(("hold_fail", node, ref))
                except BaseException:
                    arena.unpin_all((ref,))
                    outq.put(("error", node, -1, traceback.format_exc()))
            elif op == "release":
                arena.unpin_all((msg[1],))
            elif op == "pack":
                # compressed-wire lease: pin + stage encoded payload.
                # Runs inline (like "hold") so concurrent pack requests
                # for one ref can't race the staging-segment create.
                run_pack(msg[1], msg[2])
            elif op == "unpack":
                drop_pack(msg[1])
            elif op == "fault":
                # master needs a spilled tile hot (XFER source / gather)
                pool.submit(run_fault, msg[1])
            elif op == "run":
                # session mode: (re)bind this worker to a new run's
                # graph/leaves — the arena (incl. retained tiles) persists
                ctx["g"], ctx["tile"] = msg[1], msg[2]
                ctx["leaf_nodes"], ctx["dtypes"] = msg[3], msg[4]
                ctx["resident_ids"] = msg[5]
                freed_refs.clear()      # ref names recur across runs
            elif op == "retain":
                # move a persisted output tile into the session store;
                # ack with the authoritative segment name (retain may
                # fault the tile in, renaming its segment)
                try:
                    arena.retain(msg[2], msg[1])
                    sname, dt = arena.retained_seg(msg[2])
                    outq.put(("retained", node, msg[2], sname, dt))
                except BaseException:
                    outq.put(("error", node, -1, traceback.format_exc()))
            elif op == "drop":
                arena.drop_retained(msg[1])
            elif op == "audit":
                outq.put(("audit", node, arena.stats()))
            elif op == "throttle":
                throttle[0] = float(msg[1])
            elif op == "squeeze":
                # chaos mem_squeeze: shrink the budget mid-run
                arena.set_budget(msg[1])
            elif op == "alloc_fail":
                # chaos: fail the Nth upcoming fresh allocation
                arena.arm_alloc_fail(msg[1])
            elif op == "cal":
                # clock calibration: echo the master's send stamp with
                # this process's monotonic clock (NTP-style midpoint,
                # see telemetry.estimate_clock_offset)
                outq.put(("cal", node, msg[1], time.perf_counter()))
            elif op == "stop":
                break
    for ent in packs.values():          # transient wire buffers
        _release_seg(ent[0])
    packs.clear()
    stats = arena.stats()
    arena.destroy()
    outq.put(("stats", node, stats, pid, tracer.drain()))


class ClusterExecutor:
    """Executes a planned tiled program across one process per cluster node,
    honoring the HEFT schedule's per-task node placement.

    ``workers_per_node`` overrides the per-node thread count (default:
    ``spec.workers_at(node)``); ``free_buffers=False`` keeps every segment
    alive until shutdown; ``mp_context`` picks the multiprocessing start
    method (default ``fork`` where available — workers inherit the plan
    instead of re-pickling it); ``timeout`` bounds each wait on worker
    events so a dead worker raises instead of hanging.

    ``session=True`` turns this into a session backend: the worker
    processes (and their arenas, holding the session's retained tiles)
    are spawned on the first ``execute()`` and SURVIVE across runs — each
    run ships its graph to the workers via a ``("run", ...)`` op.
    ``close_session()`` shuts the workers down and returns a per-node
    arena audit (live/retained buffer counts for the session's refcount
    audit).
    """

    def __init__(self, workers_per_node: Optional[int] = None,
                 free_buffers: bool = True,
                 mp_context: Optional[str] = None,
                 timeout: float = 300.0,
                 session: bool = False,
                 timemodel: Optional[TimeModel] = None,
                 wire_codec: Optional[str] = None,
                 broadcast: bool = True,
                 stream_gather: bool = True,
                 trace: bool = True):
        self.workers_per_node = workers_per_node
        self.free_buffers = free_buffers
        self.mp_context = mp_context
        self.timeout = timeout
        self.session = session
        #: prices the per-edge codec choice (``choose_wire_codec``); with
        #: no model the auto choice degrades to "raw"
        self.timemodel = timemodel
        #: None = auto (priced per edge); "raw"/"zlib" force one codec on
        #: every cross-node XFER (conformance tests, benchmarks)
        self.wire_codec = wire_codec
        #: route fan-out edges through a relay tree instead of N unicasts
        self.broadcast = broadcast
        #: copy gathered result tiles out as their TAKECOPY lands instead
        #: of barrier-waiting the whole run (time-to-first-tile).  Only
        #: active while the master arena is unbounded — a bounded arena
        #: could evict mid-attach, and the barrier path's lease already
        #: handles that case.
        self.stream_gather = stream_gather
        #: flight recorder: on by default (obs_bench holds the paired
        #: overhead under 5%); ``spans`` holds the last run's timeline
        #: (master + ingested worker spans, master clock) after execute()
        self.trace = trace
        self.spans: List = []
        self.stats: Dict[str, object] = {}
        self._procs: Optional[List] = None
        self._inqs: Optional[List] = None
        self._outq = None
        self._spec: Optional[ClusterSpec] = None
        self._prefix = ""
        self._broken = False

    # -- driver --------------------------------------------------------------
    def execute(self, plan) -> np.ndarray:
        import multiprocessing as mp

        g: TaskGraph = plan.program.graph
        spec: Optional[ClusterSpec] = getattr(plan, "spec", None)
        if spec is None:
            raise ValueError("ClusterExecutor needs plan.spec "
                             "(a ClusterSpec) to spawn node processes")
        residency = getattr(plan, "residency", None)
        from ..core.tiling import result_sets_of
        rsets = result_sets_of(g)
        if self.session and self._broken:
            raise RuntimeError("session cluster executor is broken "
                               "(a previous run failed); open a new session")
        if self.session and self._spec is not None and self._spec != spec:
            raise ValueError("a session cluster executor is bound to one "
                             "ClusterSpec; plan was made for a different "
                             "spec")
        sched: Schedule = plan.schedule
        node_of = {tid: p.node for tid, p in sched.placements.items()}
        missing = [tid for tid in g.tasks if tid not in node_of]
        if missing:
            raise ValueError(f"schedule places {len(node_of)} tasks but the "
                             f"graph has {len(g.tasks)}; unplaced: "
                             f"{missing[:5]}")

        method = self.mp_context or (
            "fork" if "fork" in mp.get_all_start_methods() else "spawn")
        ctx = mp.get_context(method)
        prefix = f"cmm{os.getpid()}_{next(_RUN_IDS)}_"

        # -- static dataflow: XFER endpoints, waiters, reader counts --------
        xfer_by_producer: Dict[int, List[Tuple[int, int]]] = defaultdict(list)
        for (p, _src, dst, nbytes) in sched.xfers(g):
            xfer_by_producer[p].append((dst, nbytes))
        # route each producer's fan-out through a relay tree (parent node
        # -> child nodes); below the fan-out threshold the "tree" is the
        # flat unicast star rooted at the producer's node
        bcast_children: Dict[int, Dict[int, List[int]]] = {}
        xfer_nbytes: Dict[int, int] = {}
        for p, dsts in xfer_by_producer.items():
            src = node_of[p]
            xfer_nbytes[p] = dsts[0][1]
            dstns = [d for (d, _nb) in dsts]
            min_fanout = BCAST_MIN_FANOUT if self.broadcast \
                else len(dstns) + 1
            bcast_children[p] = broadcast_tree(src, dstns,
                                               min_fanout=min_fanout)
        waiters: Dict[Tuple[int, int], List[int]] = defaultdict(list)
        xfers_left: Dict[int, int] = defaultdict(int)
        reads: Dict[Tuple[int, TileRef], int] = defaultdict(int)
        for t in g:
            n = node_of[t.tid]
            for r in t.ins:
                reads[(n, r)] += 1
            if t.kind in _CHAIN_KINDS and t.out is not None:
                reads[(n, t.out)] += 1
            for p in t.preds:
                if node_of[p] != n and edge_bytes(g, g.tasks[p], t) > 0:
                    waiters[(p, n)].append(t.tid)
                    xfers_left[t.tid] += 1
        # every relay hop reads its parent's copy: the parent's tile must
        # stay alive until each child's copy lands (freed per-hop via
        # dec_read at xfer_done)
        for p, tree in bcast_children.items():
            out = g.tasks[p].out
            for parent, kids in tree.items():
                reads[(parent, out)] += len(kids)
        master_node = spec.master
        # gather holds for takecopy'd roots; retention holds pin each
        # persisted tile on its final producer's node so end-of-run
        # refcount freeing can never free a tile the session retains
        retained_refs: Dict[TileRef, Tuple[int, int]] = {}
        for rs in rsets:
            if rs.gather:
                for r in rs.tiles:
                    reads[(master_node, r)] += 1
            else:
                for r in rs.tiles:
                    home = node_of[rs.producers[r]]
                    reads[(home, r)] += 1
                    retained_refs[r] = (rs.uid, home)
        # streaming-gather targets: result tiles copied out as their
        # TAKECOPY lands, overlapped with remaining compute.  Active only
        # while the master arena is unbounded — the reads hold keeps each
        # tile's segment alive until the streamed copy succeeds, so the
        # lease-free attach cannot race a free (and the barrier path
        # remains the fallback for anything not streamed)
        stream_on = self.stream_gather and spec.mem_at(master_node) is None
        gather_uid: Dict[TileRef, int] = {}
        gvals: Dict[int, Dict[TileRef, np.ndarray]] = {}
        for rs in rsets:
            if rs.gather:
                gvals[rs.uid] = {}
                if stream_on:
                    for r in rs.tiles:
                        gather_uid[r] = rs.uid

        # -- spawn one worker process per node (session: reuse) -------------
        if self.session and self._procs is not None:
            outq, inqs, procs = self._outq, self._inqs, self._procs
            prefix = self._prefix
        else:
            outq = ctx.Queue()
            inqs = [ctx.Queue() for _ in range(spec.n_nodes)]
            procs = []
            spill_dir = run_spill_dir(prefix)
            for n in range(spec.n_nodes):
                nthreads = self.workers_per_node or spec.workers_at(n)
                args = (n, inqs[n], outq, None, None, None, None,
                        nthreads, prefix) if self.session else \
                    (n, inqs[n], outq, g, plan.tile,
                     plan.program.leaf_nodes, plan.program.dtypes,
                     nthreads, prefix)
                args = args + (0.0, None, spec.mem_at(n), spill_dir,
                               self.trace)
                p = ctx.Process(target=_node_worker, args=args, daemon=True)
                p.start()
                procs.append(p)
            if self.session:
                self._procs, self._inqs, self._outq = procs, inqs, outq
                self._spec, self._prefix = spec, prefix
        if self.session:
            # ship this run's context; RESIDENT leaves are resolved worker-
            # side via their handle ids (the handles stay master-side)
            worker_leafs = {uid: n for uid, n in
                            plan.program.leaf_nodes.items()
                            if n.op is not Op.RESIDENT}
            rids = residency.resident_ids() if residency is not None else {}
            run_msg = ("run", g, plan.tile, worker_leafs,
                       plan.program.dtypes, rids)
            for q in inqs:
                q.put(run_msg)

        seg_info: Dict[Tuple[int, TileRef], Tuple[str, str]] = {}
        exec_nodes: Dict[int, int] = {}
        node_pids: Dict[int, int] = {}
        deps_left = {t.tid: len(t.preds) for t in g}
        dispatched = set()
        # unified metrics registry (replaces the ad-hoc counters dict):
        # inc() is the atomic path, frozen_view() the read-only dict the
        # stats consumers have always read
        counters = MetricsRegistry()
        for _k in ("xfers", "xfer_bytes", "wire_bytes",
                   "xfers_compressed", "relay_hops",
                   "gather_streamed_tiles"):
            counters.inc(_k, 0)
        # flight recorder: master-side tracer (node -1 = the master lane)
        # plus per-node clock offsets from the NTP-style cal handshake —
        # worker spans ingest onto the master timeline
        tracer = Tracer(node=-1, enabled=self.trace)
        clock_offsets: Dict[int, float] = {}
        if self.trace:
            for n in range(spec.n_nodes):
                inqs[n].put(("cal", time.perf_counter()))
        t_exec0 = time.perf_counter()
        gather_t_first = [None]          # seconds to first gathered tile

        def dec_read(n: int, r: TileRef) -> None:
            if not self.free_buffers:
                return
            key = (n, r)
            c = reads.get(key)
            if c is None:
                return
            if c <= 1:
                del reads[key]
                spilled.discard(key)
                fault_pending.discard(key)
                parked_xfers.pop(key, None)
                parked_packs.pop(key, None)
                inqs[n].put(("free", r))
            else:
                reads[key] = c - 1

        def maybe_dispatch(tid: int) -> None:
            if tid in dispatched:
                return
            if deps_left[tid] == 0 and xfers_left[tid] == 0:
                dispatched.add(tid)
                inqs[node_of[tid]].put(("task", tid))

        def next_event():
            deadline = time.monotonic() + self.timeout
            while True:
                wait = min(10.0, max(0.1, deadline - time.monotonic()))
                try:
                    return outq.get(timeout=wait)
                except _queue.Empty:
                    dead = [i for i, p in enumerate(procs)
                            if not p.is_alive()]
                    if dead:
                        raise RuntimeError(
                            f"cluster worker process(es) {dead} died "
                            f"(exit codes "
                            f"{[procs[i].exitcode for i in dead]})")
                    if time.monotonic() >= deadline:
                        raise RuntimeError(
                            f"cluster execution stalled: no worker event "
                            f"within timeout={self.timeout}s")

        total = len(g)
        done = 0
        phase = ["run"]
        # -- spill-tier master state: where evicted tiles are, which
        # fault-ins are outstanding, which XFERs wait on them
        spilled: set = set()
        fault_pending: set = set()
        held_acks: set = set()
        #: dispatched XFER attempts holding a source lease:
        #: (version, dst) -> (lease node, codec) — the release/unpack
        #: must go to the hop's actual source, which under a relay tree
        #: is not necessarily the producer's node
        leased_attempts: Dict[Tuple[int, int], Tuple[int, str]] = {}
        #: (version, dst) -> hop source node (relay parent) for retries
        #: and per-hop reader accounting
        xfer_parent: Dict[Tuple[int, int], int] = {}
        parked_xfers: Dict[Tuple[int, TileRef],
                           List[Tuple[int, int]]] = defaultdict(list)
        #: like parked_xfers but for the compressed (pack) lease path
        parked_packs: Dict[Tuple[int, TileRef],
                           List[Tuple[int, int, str]]] = defaultdict(list)
        xfer_retries: Dict[Tuple[int, int], int] = defaultdict(int)
        hold_retries: Dict[Tuple[int, TileRef], int] = defaultdict(int)
        task_ao_retries: Dict[int, int] = defaultdict(int)
        pending_retain: Dict[Tuple[int, int, int],
                             Tuple[int, TileRef]] = {}
        node_stats: Dict[int, Dict[str, int]] = {}
        node_audits: Dict[int, Dict[str, int]] = {}

        def request_fault(n: int, ref: TileRef) -> None:
            spilled.add((n, ref))
            if (n, ref) not in fault_pending:
                fault_pending.add((n, ref))
                inqs[n].put(("fault", ref))

        cur_crc: Dict[Tuple[int, TileRef], int] = {}

        def wire_codec_for(nbytes: int, src_n: int, dst_n: int) -> str:
            if src_n == dst_n:
                return "raw"
            if self.wire_codec is not None:
                return self.wire_codec
            if self.timemodel is None:
                return "raw"
            return choose_wire_codec(nbytes, spec.bandwidth(src_n, dst_n),
                                     self.timemodel)

        def send_xfer(version: int, ref: TileRef, src_n: int, dst_n: int,
                      retry: bool = False) -> None:
            """Dispatch one XFER hop src_n -> dst_n of ``version``'s out
            tile, choosing the priced wire codec per edge.  Compressed
            hops and hops out of a bounded arena go through a source-side
            lease (pack/hold); retries always lease."""
            nbytes = xfer_nbytes.get(version, ref.bytes)
            codec = wire_codec_for(nbytes, src_n, dst_n)
            xfer_parent[(version, dst_n)] = src_n
            if not retry:
                counters.inc("xfers")
                counters.inc("xfer_bytes", nbytes)
                if src_n != node_of[version]:
                    counters.inc("relay_hops")
            if codec != "raw":
                if not retry:
                    counters.inc("xfers_compressed")
                parked_packs[(src_n, ref)].append((version, dst_n, codec))
                inqs[src_n].put(("pack", ref, codec))
            elif retry or spec.mem_at(src_n) is not None:
                # bounded source arena: dispatching the done message's
                # segment name directly races eviction — lease the tile
                # instead (pin on the source, released at xfer_done)
                if not retry:
                    counters.inc("wire_bytes", nbytes)
                parked_xfers[(src_n, ref)].append((version, dst_n))
                inqs[src_n].put(("hold", ref))
            else:
                counters.inc("wire_bytes", nbytes)
                sname, sdt = seg_info[(src_n, ref)]
                inqs[dst_n].put(("xfer", version, ref, sname, sdt))

        def release_lease(version: int, dst_n: int, ref: TileRef) -> None:
            ent = leased_attempts.pop((version, dst_n), None)
            if ent is not None:
                src_n, codec = ent
                inqs[src_n].put(("release", ref) if codec == "raw"
                                else ("unpack", ref))

        def try_stream_gather(r: TileRef) -> None:
            """Copy one landed result tile out during the main loop.  Any
            failure falls back silently to the barrier gather (its reads
            hold is only dropped on success)."""
            uid = gather_uid.get(r)
            if uid is None or r in gvals[uid]:
                return
            if (master_node, r) in spilled:
                return
            ent = seg_info.get((master_node, r))
            if ent is None:             # pragma: no cover - defensive
                return
            try:
                seg = _attach_shm(ent[0])
            except FileNotFoundError:   # pragma: no cover - defensive
                return
            try:
                view = np.ndarray(r.shape, dtype=np.dtype(ent[1]),
                                  buffer=seg.buf)
                val = view.copy()
            finally:
                seg.close()
            if _CRCAUDIT:
                crc_check("gather", master_node, r,
                          zlib.crc32(val.data) & 0xFFFFFFFF)
            gvals[uid][r] = val
            counters.inc("gather_streamed_tiles")
            if gather_t_first[0] is None:
                gather_t_first[0] = time.perf_counter() - t_exec0
            dec_read(master_node, r)

        def crc_check(stage: str, n: int, ref: TileRef, crc) -> None:
            if crc is None:
                return
            prev = cur_crc.get((n, ref))
            if prev is not None and prev != crc:
                import sys as _sys
                line = (f"CRCAUDIT MISMATCH stage={stage} node={n} "
                        f"ref={ref} prev={prev:#010x} now={crc:#010x}")
                print(line, file=_sys.stderr, flush=True)
            cur_crc[(n, ref)] = crc

        def handle(msg) -> None:
            nonlocal done
            kind = msg[0]
            if kind == "done":
                _, n, tid, seg, dt, pid, _dur, *rest = msg
                if len(rest) > 1:
                    tracer.ingest(rest[1], clock_offsets.get(n, 0.0))
                counters.observe("task_seconds", _dur)
                t = g.tasks[tid]
                if seg is not None and t.out is not None:
                    seg_info[(n, t.out)] = (seg, dt)
                    if rest and rest[0] is not None:
                        # a task legitimately (re)writes its out tile
                        cur_crc[(n, t.out)] = rest[0]
                exec_nodes[tid] = n
                node_pids[n] = pid
                done += 1
                # root hops of the (possibly flat) relay tree; deeper
                # hops start as each relay's copy lands (xfer_done)
                for child in bcast_children.get(tid, {}).get(n, ()):
                    send_xfer(tid, t.out, n, child)
                for s in sorted(t.succs):
                    deps_left[s] -= 1
                    maybe_dispatch(s)
                for r in t.ins:
                    dec_read(n, r)
                if t.kind in _CHAIN_KINDS and t.out is not None:
                    dec_read(n, t.out)
                if t.kind is TaskKind.TAKECOPY and n == master_node \
                        and phase[0] == "run":
                    try_stream_gather(t.out)
            elif kind == "xfer_done":
                _, n, version, ref, seg, dt, *rest = msg
                if len(rest) > 1:
                    tracer.ingest(rest[1], clock_offsets.get(n, 0.0))
                seg_info[(n, ref)] = (seg, dt)
                # the copy landed: release the hop source's lease
                release_lease(version, n, ref)
                hop_src = xfer_parent.pop((version, n), node_of[version])
                if rest and rest[0] is not None:
                    src_crc = cur_crc.get((hop_src, ref))
                    if src_crc is not None and src_crc != rest[0]:
                        import sys as _sys
                        print(f"CRCAUDIT MISMATCH stage=xfer "
                              f"src={hop_src} dst={n} ref={ref} "
                              f"src_crc={src_crc:#010x} "
                              f"dst_crc={rest[0]:#010x}",
                              file=_sys.stderr, flush=True)
                    cur_crc[(n, ref)] = rest[0]
                dec_read(hop_src, g.tasks[version].out)
                # the landed copy relays onward to its broadcast children
                for child in bcast_children.get(version, {}).get(n, ()):
                    send_xfer(version, ref, n, child)
                for s in waiters.pop((version, n), ()):
                    xfers_left[s] -= 1
                    maybe_dispatch(s)
            elif kind == "spill":
                spilled.add((msg[1], msg[2]))
                if len(msg) > 3:
                    crc_check("spill", msg[1], msg[2], msg[3])
            elif kind == "unspill":
                _, n, ref, sname, dt, *rest = msg
                if rest:
                    crc_check("unspill", n, ref, rest[0])
                seg_info[(n, ref)] = (sname, dt)
                spilled.discard((n, ref))
                fault_pending.discard((n, ref))
            elif kind == "held":
                # source-side lease granted: the segment name is pinned
                # until the matching "release", so parked XFERs can
                # attach it without racing eviction
                _, n, ref, sname, dt, *rest = msg
                if rest:
                    crc_check("held", n, ref, rest[0])
                seg_info[(n, ref)] = (sname, dt)
                spilled.discard((n, ref))
                fault_pending.discard((n, ref))
                held_acks.add((n, ref))
                hold_retries.pop((n, ref), None)
                for (version, dstn) in parked_xfers.pop((n, ref), ()):
                    leased_attempts[(version, dstn)] = (n, "raw")
                    inqs[dstn].put(("xfer", version, ref, sname, dt))
            elif kind == "packed":
                # compressed-wire lease granted: the staging segment
                # holds the encoded payload, pinned until "unpack"
                _, n, ref, sname, dt, codec, comp_nbytes, raw_crc = msg
                hold_retries.pop((n, ref), None)
                for (version, dstn, _c) in parked_packs.pop((n, ref), ()):
                    counters.inc("wire_bytes", comp_nbytes)
                    leased_attempts[(version, dstn)] = (n, codec)
                    inqs[dstn].put(("xfer", version, ref, sname, dt,
                                    codec, comp_nbytes, raw_crc))
            elif kind == "hold_fail":
                # transient source-side overflow faulting the tile hot:
                # re-send the hold/pack — each round trip is natural
                # backoff while in-flight tasks drain their pins
                _, n, ref = msg
                hold_retries[(n, ref)] += 1
                if hold_retries[(n, ref)] > 100:
                    raise MemoryBudgetExceeded(
                        n, 0, spec.mem_at(n) or 0,
                        msg=f"node {n} could not fault {ref} hot for an "
                            f"XFER/gather lease after "
                            f"{hold_retries[(n, ref)]} attempts (arena "
                            f"persistently full of pinned tiles)")
                if parked_packs.get((n, ref)):
                    inqs[n].put(("pack", ref, parked_packs[(n, ref)][0][2]))
                else:
                    inqs[n].put(("hold", ref))
            elif kind == "tile_lost":
                # static membership has no lineage machinery to recompute
                # a lost intermediate — structured failure, not an OOM
                raise RuntimeError(
                    f"spilled tile {msg[2]} lost on node {msg[1]} "
                    f"(missing/corrupt spill file); the static cluster "
                    f"executor cannot lineage-recompute — use the elastic "
                    f"executor for graceful degradation:\n{msg[3]}")
            elif kind == "retained":
                _, n, key, sname, dt = msg
                ent = pending_retain.pop(key, None)
                if ent is not None:
                    uid, r = ent
                    residency.retain_seg(uid, r.i, r.j, n, sname, dt)
            elif kind == "audit":
                node_audits[msg[1]] = msg[2]
            elif kind == "stats":
                node_stats[msg[1]] = msg[2]
                node_pids.setdefault(msg[1], msg[3])
                if len(msg) > 4:
                    tracer.ingest(msg[4], clock_offsets.get(msg[1], 0.0))
            elif kind == "cal":
                # worker's clock echo: NTP-style midpoint offset, under
                # which worker span timestamps map onto the master clock
                clock_offsets[msg[1]] = estimate_clock_offset(
                    msg[2], msg[3], time.perf_counter())
            elif kind == "error":
                if "ArenaOverflow" in msg[3]:
                    # often transient: concurrent tasks' pinned inputs
                    # drain as they complete — bounded re-dispatch (the
                    # failure is pre-mutation, so chains are safe too)
                    if msg[2] >= 0:
                        task_ao_retries[msg[2]] += 1
                        if task_ao_retries[msg[2]] <= 3:
                            inqs[msg[1]].put(("task", msg[2]))
                            return
                    raise MemoryBudgetExceeded(
                        msg[1], 0, spec.mem_at(msg[1]) or 0,
                        msg=f"node {msg[1]} arena overflow (budget "
                            f"{spec.mem_at(msg[1])} bytes, nothing left "
                            f"to evict) during {phase[0]}:\n{msg[3]}")
                raise RuntimeError(
                    f"cluster task failed on node {msg[1]} "
                    f"(task {msg[2]}) during {phase[0]}:\n{msg[3]}")
            elif kind == "xfer_fail":
                _, dstn, version, ref, tb = msg
                # static membership: recoverable causes are the source
                # segment having been spilled between the producer's done
                # and the consumer's attach, or a transient destination
                # arena overflow — re-request through a source fault-in
                # (its ack round-trip doubles as backoff), bounded;
                # anything else is a broken run
                src = xfer_parent.get((version, dstn), node_of[version])
                # the failed attempt's lease is still held — drop it
                # (the retry takes a fresh one)
                release_lease(version, dstn, ref)
                xfer_retries[(version, dstn)] += 1
                if xfer_retries[(version, dstn)] > 3:
                    if "ArenaOverflow" in tb:
                        raise MemoryBudgetExceeded(
                            dstn, 0, spec.mem_at(dstn) or 0,
                            msg=f"node {dstn} arena overflow receiving "
                                f"XFER of {ref}:\n{tb}")
                    raise RuntimeError(
                        f"cluster XFER of {ref} (version {version}) "
                        f"failed on node {dstn} after "
                        f"{xfer_retries[(version, dstn)]} attempts:\n{tb}")
                send_xfer(version, ref, src, dstn, retry=True)

        try:
            for t in g.sources():
                maybe_dispatch(t.tid)
            while done < total:
                handle(next_event())

            # -- gather result tiles from the master node's arena ----------
            outs: List[np.ndarray] = []
            gather_bytes = 0
            retained = 0
            phase[0] = "gather"
            gather_span_t0 = time.perf_counter()
            for rs in rsets:
                if not rs.gather:
                    continue
                vals: Dict[TileRef, np.ndarray] = gvals.get(rs.uid, {})
                for r in rs.tiles:
                    if r in vals:       # already streamed mid-run
                        gather_bytes += r.bytes
                        continue
                    leased = spec.mem_at(master_node) is not None
                    if leased:
                        # lease the tile hot for the attach (same race
                        # as XFER sources: the worker keeps allocating
                        # while we read)
                        held_acks.discard((master_node, r))
                        inqs[master_node].put(("hold", r))
                        while (master_node, r) not in held_acks:
                            handle(next_event())
                    try:
                        for _attempt in range(5):
                            if (master_node, r) in spilled:
                                request_fault(master_node, r)
                                while (master_node, r) in spilled:
                                    handle(next_event())
                            sname, dt = seg_info[(master_node, r)]
                            try:
                                seg = _attach_shm(sname)
                            except FileNotFoundError:
                                # evicted between unspill and attach
                                request_fault(master_node, r)
                                continue
                            try:
                                view = np.ndarray(r.shape,
                                                  dtype=np.dtype(dt),
                                                  buffer=seg.buf)
                                vals[r] = view.copy()
                            finally:
                                seg.close()
                            if _CRCAUDIT:
                                crc_check(
                                    "gather", master_node, r,
                                    zlib.crc32(vals[r].data) & 0xFFFFFFFF)
                            break
                        else:
                            raise RuntimeError(
                                f"could not gather result tile {r}: "
                                f"segment kept vanishing under memory "
                                f"pressure")
                    finally:
                        if leased:
                            inqs[master_node].put(("release", r))
                    gather_bytes += r.bytes
                    if gather_t_first[0] is None:
                        gather_t_first[0] = time.perf_counter() - t_exec0
                    dec_read(master_node, r)
                outs.append(assemble(vals, rs.shape, plan.tile, rs.uid))

            gather_t_full = time.perf_counter() - t_exec0
            if self.trace:
                # one master-lane span for the (barrier) gather phase, so
                # the trace shows result assembly against worker compute
                tracer.add(Span("GATHER", "GATHER", -1, 0, gather_span_t0,
                                time.perf_counter() - gather_span_t0,
                                {"bytes": gather_bytes}))

            # -- retention: persisted tiles move to the session store -------
            phase[0] = "retention"
            for r, (uid, home) in retained_refs.items():
                h = residency.retain[uid]
                pending_retain[(h.hid, r.i, r.j)] = (uid, r)
                inqs[home].put(("retain", r, (h.hid, r.i, r.j)))
                retained += 1

            # -- orderly shutdown + per-node stats --------------------------
            if self.session:
                # workers survive; audit instead of stop (per-worker FIFO
                # means the audit reply confirms every retain op above was
                # processed — its ack handled on the way)
                for q in inqs:
                    q.put(("audit",))
                while len(node_audits) < spec.n_nodes:
                    handle(next_event())
                while pending_retain:       # pragma: no cover - FIFO order
                    handle(next_event())
                node_stats = node_audits
            else:
                for q in inqs:
                    q.put(("stop",))
                while len(node_stats) < spec.n_nodes:
                    handle(next_event())
                for p in procs:
                    p.join(timeout=self.timeout)
        except BaseException:
            self._broken = True
            for p in procs:
                if p.is_alive():
                    p.terminate()
            # best-effort unlink of segments the (terminated) workers own;
            # tracker register/unregister are silenced — these names were
            # registered by the workers' trackers, not the master's
            from multiprocessing import resource_tracker, shared_memory
            with _TRACK_LOCK:
                orig = (resource_tracker.register,
                        resource_tracker.unregister)
                resource_tracker.register = lambda *a, **kw: None
                resource_tracker.unregister = lambda *a, **kw: None
                try:
                    names = {sname for (sname, _dt) in seg_info.values()}
                    if os.path.isdir("/dev/shm"):
                        # segments allocated but not yet reported when the
                        # workers were terminated are only findable by the
                        # run's namespace prefix
                        names.update(f for f in os.listdir("/dev/shm")
                                     if f.startswith(prefix))
                    for sname in names:
                        try:
                            _release_seg(
                                shared_memory.SharedMemory(name=sname))
                        except FileNotFoundError:
                            pass
                finally:
                    (resource_tracker.register,
                     resource_tracker.unregister) = orig
            shutil.rmtree(run_spill_dir(prefix), ignore_errors=True)
            raise
        finally:
            if not self.session or self._broken:
                for p in procs:
                    if p.is_alive():        # pragma: no cover
                        p.terminate()
                        p.join(timeout=5)

        leaked_spill = 0
        if not self.session:
            # after a clean non-session stop every spill file must be gone;
            # leftovers are leaks (counted, then reaped)
            sd = run_spill_dir(prefix)
            try:
                leaked_spill = len(os.listdir(sd))
            except OSError:
                leaked_spill = 0
            shutil.rmtree(sd, ignore_errors=True)

        # the registry's frozen_view IS the stats dict consumers always
        # read — counters stay inside the registry, run-shaped facts ride
        # along as extras
        self.spans = tracer.drain()
        self.stats = counters.frozen_view({
            "tasks_run": total,
            "workers": sum(self.workers_per_node or spec.workers_at(n)
                           for n in range(spec.n_nodes)),
            "nodes": spec.n_nodes,
            "gather_bytes": gather_bytes,
            "gather_first_tile_s": gather_t_first[0],
            "gather_full_result_s": gather_t_full,
            # must be 0 after a clean run: an open lease is a stranded
            # source pin on some worker's (possibly bounded) arena
            "stale_leases": len(leased_attempts)
            + sum(len(v) for v in parked_xfers.values())
            + sum(len(v) for v in parked_packs.values()),
            "retained_tiles": retained,
            "peak_buffer_bytes": sum(s["peak_buffer_bytes"]
                                     for s in node_stats.values()),
            "cur_buffer_bytes": sum(s["cur_buffer_bytes"]
                                    for s in node_stats.values()),
            "buffers_freed": sum(s["buffers_freed"]
                                 for s in node_stats.values()),
            "live_buffers": sum(s.get("live_buffers", 0)
                                for s in node_stats.values()),
            "retained_total": sum(s.get("retained", 0)
                                  for s in node_stats.values()),
            "evictions": sum(s.get("evictions", 0)
                             for s in node_stats.values()),
            "faults": sum(s.get("faults", 0)
                          for s in node_stats.values()),
            "spill_writes": sum(s.get("spill_writes", 0)
                                for s in node_stats.values()),
            "spill_reads": sum(s.get("spill_reads", 0)
                               for s in node_stats.values()),
            "spilled_bytes": sum(s.get("spilled_bytes", 0)
                                 for s in node_stats.values()),
            "leaked_spill_files": leaked_spill,
            "exec_nodes": exec_nodes,
            "node_pids": node_pids,
        })
        if not outs:
            return None
        return outs[0] if len(outs) == 1 else outs

    # -- session lifecycle ----------------------------------------------------
    def drop_retained(self, node: int, key) -> None:
        """Session free path: drop one retained tile from ``node``'s arena."""
        if self._inqs is not None and not self._broken:
            self._inqs[node].put(("drop", key))

    def close_session(self) -> Dict[int, Dict[str, int]]:
        """Stop the long-lived workers; returns the per-node arena stats
        collected at shutdown (live/retained buffer counts — the session's
        refcount audit input)."""
        audit: Dict[int, Dict[str, int]] = {}
        if self._procs is None:
            return audit
        if not self._broken:
            for q in self._inqs:
                q.put(("stop",))
            deadline = time.monotonic() + min(self.timeout, 30.0)
            while len(audit) < len(self._procs) and \
                    time.monotonic() < deadline:
                try:
                    msg = self._outq.get(timeout=0.5)
                except _queue.Empty:
                    if all(not p.is_alive() for p in self._procs):
                        break
                    continue
                if msg[0] == "stats":
                    audit[msg[1]] = msg[2]
        for p in self._procs:
            p.join(timeout=5)
            if p.is_alive():                     # pragma: no cover
                p.terminate()
        self._procs = self._inqs = self._outq = None
        # spill-file leak sweep: a clean shutdown leaves the run's spill
        # directory empty — report leftovers so the session audit can fail
        if self._prefix:
            sd = run_spill_dir(self._prefix)
            try:
                leaked = len(os.listdir(sd))
            except OSError:
                leaked = 0
            shutil.rmtree(sd, ignore_errors=True)
            audit["spill"] = {"leaked_spill_files": leaked}
        return audit


#: unique per-execute() shm namespace within this master process
_RUN_IDS = itertools.count()


def predict_cluster_makespan(g: TaskGraph, sched: Schedule,
                             spec: ClusterSpec, tm: TimeModel) -> float:
    """Predicted wall-clock of the multi-process cluster executor.

    Re-simulates the schedule with the machine model swapped to what this
    backend actually pays: per-task process dispatch
    (``tm.process_dispatch_overhead``) and shared-memory XFER transfers
    (``tm.ipc_latency + bytes / tm.ipc_bandwidth``) instead of the network
    link model.  The engine compares this against the per-task and
    wave-batched predictions to pick ``executor="auto"``'s strategy.
    """
    from ..core.simulator import simulate
    ipc_spec = replace(spec, link_bw=max(tm.ipc_bandwidth, 1.0),
                       latency=max(tm.ipc_latency, 0.0), pair_bw=())
    tm_proc = replace(tm, dispatch_overhead=tm.process_dispatch_overhead)
    return simulate(g, sched, ipc_spec, tm_proc).makespan
