"""Executor backends + the single name -> factory registry.

``EXECUTORS`` is the one place a backend is named: ``CMMEngine.run``,
benchmarks and tests all resolve executor strings through
``make_executor``, so adding a backend is one registry line.
"""
from typing import Callable, Dict

from .local import LocalExecutor                                # noqa: F401
from .batched import (WaveExecutor, build_waves,                # noqa: F401
                      predict_wave_makespan)
from .cluster import (ClusterExecutor,                          # noqa: F401
                      predict_cluster_makespan)
from .elastic import ChaosEvent, ElasticClusterExecutor         # noqa: F401

#: executor name -> zero-arg-capable factory (kwargs forwarded verbatim)
EXECUTORS: Dict[str, Callable] = {
    # per-task threaded executor (the correctness oracle's twin)
    "local": LocalExecutor,
    # per-task with Pallas addmul tiles
    "kernel": lambda **kw: LocalExecutor(use_pallas=True, **kw),
    # wave-batched stacked-kernel executor
    "batched": lambda **kw: WaveExecutor(backend="numpy", **kw),
    # wave-batched, ADDMUL groups through jax.vmap over the Pallas GEMM
    "batched-pallas": lambda **kw: WaveExecutor(backend="pallas", **kw),
    # one process per ClusterSpec node, HEFT placements executed for real
    "cluster": ClusterExecutor,
    # cluster execution under membership churn: heartbeats, lineage
    # recovery, frontier re-planning, speculative straggler duplicates
    "elastic": ElasticClusterExecutor,
}


def make_executor(name: str, **kw):
    """Instantiate a registered executor backend by name."""
    try:
        factory = EXECUTORS[name]
    except KeyError:
        raise ValueError(
            f"unknown executor {name!r}; known: {sorted(EXECUTORS)}"
        ) from None
    return factory(**kw)
