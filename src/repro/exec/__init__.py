from .local import LocalExecutor                                # noqa: F401
from .batched import (WaveExecutor, build_waves,                # noqa: F401
                      predict_wave_makespan)
