from .local import LocalExecutor  # noqa: F401
