"""Pure-jnp oracles for every Pallas kernel (the allclose reference)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul(a: jax.Array, b: jax.Array) -> jax.Array:
    out_dtype = jnp.promote_types(a.dtype, b.dtype)
    return jnp.dot(a, b, preferred_element_type=jnp.float32).astype(out_dtype)


def addmul(c: jax.Array, a: jax.Array, b: jax.Array) -> jax.Array:
    acc = c.astype(jnp.float32) + jnp.dot(a, b,
                                          preferred_element_type=jnp.float32)
    return acc.astype(c.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, scale: float | None = None
                    ) -> jax.Array:
    """(B, H, S, D) attention oracle, fp32 softmax."""
    if scale is None:
        scale = 1.0 / (q.shape[-1] ** 0.5)
    logits = jnp.einsum("bhsd,bhtd->bhst", q, k,
                        preferred_element_type=jnp.float32) * scale
    if causal:
        s, t = logits.shape[-2:]
        mask = jnp.tril(jnp.ones((s, t), bool), k=t - s)
        logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhst,bhtd->bhsd", probs.astype(v.dtype), v)
    return out.astype(q.dtype)
