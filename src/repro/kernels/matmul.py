"""Pallas blocked GEMM / GEMM-accumulate — CMM's ``addmul`` task on TPU.

The paper's hot task is ``C_ij += A_ik @ B_kj`` on an L3-cache-tiled CPU
BLAS.  The TPU adaptation re-tiles for the memory hierarchy HBM -> VMEM ->
MXU: the ``pallas_call`` grid walks (i, j, k) output/contraction blocks, each
step streaming one (bm, bk) A-block and one (bk, bn) B-block into VMEM,
feeding the 128x128 systolic MXU, and accumulating into a float32 VMEM
scratch that is written back to HBM once per (i, j) block (on the last k
step).  Block sizes default to MXU-aligned 128 multiples; the CMM autotuner
(core/autotune.py) selects them with the same simulate-candidates loop the
paper uses for tile sizes.

Kernels:
  * ``matmul_kernel``  — C = A @ B
  * ``addmul_kernel``  — C = C_in + A @ B   (the paper's addmul, fused)
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mm_kernel(a_ref, b_ref, o_ref, acc_ref, *, nk: int):
    """Grid (i, j, k); k is the minor-most (fastest) dimension."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _addmul_kernel(c_ref, a_ref, b_ref, o_ref, acc_ref, *, nk: int):
    """o = c + a @ b ; accumulator seeded from the C block (fused addmul)."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = c_ref[...].astype(jnp.float32)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _pad_to(x: jax.Array, mult: Tuple[int, int]) -> jax.Array:
    m, n = x.shape
    pm = (-m) % mult[0]
    pn = (-n) % mult[1]
    if pm or pn:
        x = jnp.pad(x, ((0, pm), (0, pn)))
    return x


def _blocks(dim: int, blk: int) -> int:
    return -(-dim // blk)


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "interpret"))
def matmul(a: jax.Array, b: jax.Array, *, block_m: int = 128,
           block_n: int = 128, block_k: int = 128,
           interpret: bool = False) -> jax.Array:
    """C = A @ B via the blocked Pallas kernel.  Ragged shapes are padded to
    block multiples and the result sliced back (edge-tile handling)."""
    if a.ndim != 2 or b.ndim != 2 or a.shape[1] != b.shape[0]:
        raise ValueError(f"bad matmul shapes {a.shape} @ {b.shape}")
    m, kdim = a.shape
    _, n = b.shape
    out_dtype = jnp.promote_types(a.dtype, b.dtype)
    ap = _pad_to(a, (block_m, block_k))
    bp = _pad_to(b, (block_k, block_n))
    gm, gn, gk = (_blocks(m, block_m), _blocks(n, block_n),
                  _blocks(kdim, block_k))
    out = pl.pallas_call(
        functools.partial(_mm_kernel, nk=gk),
        grid=(gm, gn, gk),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_k, block_n), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((gm * block_m, gn * block_n),
                                       out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(ap, bp)
    return out[:m, :n]


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "interpret"))
def addmul(c: jax.Array, a: jax.Array, b: jax.Array, *, block_m: int = 128,
           block_n: int = 128, block_k: int = 128,
           interpret: bool = False) -> jax.Array:
    """CMM's addmul: C + A @ B, fused (C is read block-wise into the VMEM
    accumulator — no separate add pass over HBM)."""
    m, kdim = a.shape
    _, n = b.shape
    if c.shape != (m, n):
        raise ValueError(f"bad addmul shapes {c.shape} + {a.shape}@{b.shape}")
    out_dtype = c.dtype
    ap = _pad_to(a, (block_m, block_k))
    bp = _pad_to(b, (block_k, block_n))
    cp = _pad_to(c, (block_m, block_n))
    gm, gn, gk = (_blocks(m, block_m), _blocks(n, block_n),
                  _blocks(kdim, block_k))
    out = pl.pallas_call(
        functools.partial(_addmul_kernel, nk=gk),
        grid=(gm, gn, gk),
        in_specs=[
            pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_k, block_n), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((gm * block_m, gn * block_n),
                                       out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(cp, ap, bp)
    return out[:m, :n]
