"""Pallas blocked GEMM / GEMM-accumulate — CMM's ``addmul`` task on TPU.

The paper's hot task is ``C_ij += A_ik @ B_kj`` on an L3-cache-tiled CPU
BLAS.  The TPU adaptation re-tiles for the memory hierarchy HBM -> VMEM ->
MXU: the ``pallas_call`` grid walks (i, j, k) output/contraction blocks, each
step streaming one (bm, bk) A-block and one (bk, bn) B-block into VMEM,
feeding the 128x128 systolic MXU, and accumulating into a float32 VMEM
scratch that is written back to HBM once per (i, j) block (on the last k
step).  Block sizes default to MXU-aligned 128 multiples; the CMM autotuner
(core/autotune.py) selects them with the same simulate-candidates loop the
paper uses for tile sizes.

Kernels:
  * ``matmul_kernel``  — C = A @ B
  * ``addmul_kernel``  — C = C_in + A @ B   (the paper's addmul, fused)
  * ``addmul_epilogue`` — C_in + A @ B followed by a fused elementwise
    epilogue program (the FUSED tile-program encoding from core/fusion),
    applied to the float32 VMEM accumulator on the last k step, before the
    single HBM store.  This is the true-fusion leg of the matmul-epilogue
    optimization: the elementwise chain never round-trips through HBM.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mm_kernel(a_ref, b_ref, o_ref, acc_ref, *, nk: int):
    """Grid (i, j, k); k is the minor-most (fastest) dimension."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _addmul_kernel(c_ref, a_ref, b_ref, o_ref, acc_ref, *, nk: int):
    """o = c + a @ b ; accumulator seeded from the C block (fused addmul)."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = c_ref[...].astype(jnp.float32)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


# -- fused epilogue -------------------------------------------------------
# jnp translation of the FUSED tile-program vocabulary (core/fusion).
# The program runs on the float32 accumulator inside the kernel, so every
# op maps to a VPU-friendly jnp primitive.

_EPI_UNARY = {
    "sin": jnp.sin,
    "cos": jnp.cos,
    "exp": jnp.exp,
    "tanh": jnp.tanh,
    "abs": jnp.abs,
    "relu": lambda x: jnp.maximum(x, 0.0),
    "sqrt": jnp.sqrt,
    "sign": jnp.sign,
}


def _epi_scale(kind: str, x: jax.Array, s: float) -> jax.Array:
    if kind == "add":
        return x + s
    if kind == "sub":
        return x - s
    if kind == "rsub":
        return s - x
    if kind in ("scale", "mul", "ewmul"):
        return x * s
    if kind == "div":
        return x / s
    if kind == "rdiv":
        return s / x
    raise ValueError(f"unknown scalar op {kind}")


def eval_epilogue_jnp(prog, inputs) -> jax.Array:
    """Interpret a FUSED tile program over jnp values (last instr = out).

    Mirrors ``fusion.eval_fused`` semantics; used inside the Pallas kernel
    (on VMEM blocks) and directly for testing the translation.
    """
    vals = []
    for ins in prog:
        kind = ins[0]
        if kind == "in":
            vals.append(inputs[ins[1]])
        elif kind == "ewise":
            vals.append(_EPI_UNARY[ins[1]](vals[ins[2]]))
        elif kind == "scale":
            vals.append(_epi_scale(ins[1], vals[ins[3]], ins[2]))
        elif kind == "add":
            vals.append(vals[ins[1]] + vals[ins[2]])
        elif kind == "sub":
            vals.append(vals[ins[1]] - vals[ins[2]])
        elif kind == "ewmul":
            vals.append(vals[ins[1]] * vals[ins[2]])
        else:  # pragma: no cover
            raise ValueError(f"unknown epilogue instr {kind}")
    return vals[-1]


def _addmul_epi_kernel(*refs, nk: int, prog, nextra: int):
    """o = epilogue(c + a @ b, extras...) — epilogue on the f32 accumulator
    at the last k step, fused before the single store to HBM."""
    c_ref, a_ref, b_ref = refs[:3]
    extra_refs = refs[3:3 + nextra]
    o_ref = refs[3 + nextra]
    acc_ref = refs[4 + nextra]
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = c_ref[...].astype(jnp.float32)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _done():
        ins = [acc_ref[...]] + [r[...].astype(jnp.float32)
                                for r in extra_refs]
        o_ref[...] = eval_epilogue_jnp(prog, ins).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("prog", "block_m", "block_n", "block_k", "out_dtype",
                     "interpret"))
def addmul_epilogue(c: jax.Array, a: jax.Array, b: jax.Array, *extras,
                    prog, block_m: int = 128, block_n: int = 128,
                    block_k: int = 128, out_dtype=None,
                    interpret: bool = False) -> jax.Array:
    """Fused ``epilogue(C + A @ B, extras...)`` in one Pallas launch.

    ``prog`` is the FUSED tile program (hashable tuple; in-slot 0 is the
    accumulated C, slots 1.. are ``extras`` in order).  The accumulator
    lives in float32 VMEM, so this leg is validated at tolerance against
    the NumPy path, like the plain Pallas addmul.  ``out_dtype`` overrides
    the store dtype (the mixed-precision bf16 gate); default is the NumPy
    promotion over C and extras.
    """
    m, kdim = a.shape
    _, n = b.shape
    if c.shape != (m, n):
        raise ValueError(f"bad addmul shapes {c.shape} + {a.shape}@{b.shape}")
    for e in extras:
        if e.shape != (m, n):
            raise ValueError(f"bad epilogue extra shape {e.shape} != {(m, n)}")
    if out_dtype is None:
        out_dtype = functools.reduce(
            jnp.promote_types, [e.dtype for e in extras], c.dtype)
    ap = _pad_to(a, (block_m, block_k))
    bp = _pad_to(b, (block_k, block_n))
    cp = _pad_to(c, (block_m, block_n))
    eps = [_pad_to(e, (block_m, block_n)) for e in extras]
    gm, gn, gk = (_blocks(m, block_m), _blocks(n, block_n),
                  _blocks(kdim, block_k))
    ij_spec = pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j))
    out = pl.pallas_call(
        functools.partial(_addmul_epi_kernel, nk=gk, prog=prog,
                          nextra=len(extras)),
        grid=(gm, gn, gk),
        in_specs=[
            ij_spec,
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_k, block_n), lambda i, j, k: (k, j)),
        ] + [ij_spec] * len(extras),
        out_specs=ij_spec,
        out_shape=jax.ShapeDtypeStruct((gm * block_m, gn * block_n),
                                       out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(cp, ap, bp, *eps)
    return out[:m, :n]


def _pad_to(x: jax.Array, mult: Tuple[int, int]) -> jax.Array:
    m, n = x.shape
    pm = (-m) % mult[0]
    pn = (-n) % mult[1]
    if pm or pn:
        x = jnp.pad(x, ((0, pm), (0, pn)))
    return x


def _blocks(dim: int, blk: int) -> int:
    return -(-dim // blk)


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "interpret"))
def matmul(a: jax.Array, b: jax.Array, *, block_m: int = 128,
           block_n: int = 128, block_k: int = 128,
           interpret: bool = False) -> jax.Array:
    """C = A @ B via the blocked Pallas kernel.  Ragged shapes are padded to
    block multiples and the result sliced back (edge-tile handling)."""
    if a.ndim != 2 or b.ndim != 2 or a.shape[1] != b.shape[0]:
        raise ValueError(f"bad matmul shapes {a.shape} @ {b.shape}")
    m, kdim = a.shape
    _, n = b.shape
    out_dtype = jnp.promote_types(a.dtype, b.dtype)
    ap = _pad_to(a, (block_m, block_k))
    bp = _pad_to(b, (block_k, block_n))
    gm, gn, gk = (_blocks(m, block_m), _blocks(n, block_n),
                  _blocks(kdim, block_k))
    out = pl.pallas_call(
        functools.partial(_mm_kernel, nk=gk),
        grid=(gm, gn, gk),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_k, block_n), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((gm * block_m, gn * block_n),
                                       out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(ap, bp)
    return out[:m, :n]


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "interpret"))
def addmul(c: jax.Array, a: jax.Array, b: jax.Array, *, block_m: int = 128,
           block_n: int = 128, block_k: int = 128,
           interpret: bool = False) -> jax.Array:
    """CMM's addmul: C + A @ B, fused (C is read block-wise into the VMEM
    accumulator — no separate add pass over HBM)."""
    m, kdim = a.shape
    _, n = b.shape
    if c.shape != (m, n):
        raise ValueError(f"bad addmul shapes {c.shape} + {a.shape}@{b.shape}")
    out_dtype = c.dtype
    ap = _pad_to(a, (block_m, block_k))
    bp = _pad_to(b, (block_k, block_n))
    cp = _pad_to(c, (block_m, block_n))
    gm, gn, gk = (_blocks(m, block_m), _blocks(n, block_n),
                  _blocks(kdim, block_k))
    out = pl.pallas_call(
        functools.partial(_addmul_kernel, nk=gk),
        grid=(gm, gn, gk),
        in_specs=[
            pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_k, block_n), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((gm * block_m, gn * block_n),
                                       out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(cp, ap, bp)
    return out[:m, :n]
