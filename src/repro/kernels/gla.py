"""Pallas chunkwise gated-linear-attention kernel (mLSTM / mamba-head GLA).

The XLA-level chunkwise GLA (models/ssm.py) is memory-bound on the hymba /
xlstm cells: the per-chunk decay matrices and fp32 intermediates round-trip
HBM.  This kernel keeps the recurrent state S (dk x dv), the normaliser n
(dk), and all chunk intermediates in VMEM across the sequential chunk
walk; HBM traffic is one read of q/k/v/log_a and one write of y.

Grid: (B*H, S/chunk) with the chunk dim minor-most (sequential) — the
state scratch persists across chunk steps of the same (b, h) program,
exactly like the accumulator in the blocked-GEMM kernel.

Layout notes: q/k/v arrive (B*H, S, d) so each block is a (chunk, d)
VMEM tile; per-step scalar decays arrive (B*H, S, 1).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gla_kernel(q_ref, k_ref, v_ref, la_ref, y_ref, state_ref, norm_ref, *,
                nc: int, chunk: int, normalize: bool):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)
        norm_ref[...] = jnp.zeros_like(norm_ref)

    q = q_ref[0].astype(jnp.float32)          # (c, dk)
    k = k_ref[0].astype(jnp.float32)          # (c, dk)
    v = v_ref[0].astype(jnp.float32)          # (c, dv)
    la = la_ref[0].astype(jnp.float32)        # (c, 1)

    F = jnp.cumsum(la, axis=0)                # (c, 1)
    total = F[-1]                             # (1,)
    S_prev = state_ref[...]                   # (dk, dv)
    n_prev = norm_ref[...]                    # (dk, 1)

    q_dec = q * jnp.exp(F)                    # (c, dk)
    y_inter = jnp.dot(q_dec, S_prev, preferred_element_type=jnp.float32)
    n_inter = jnp.dot(q_dec, n_prev, preferred_element_type=jnp.float32)

    qk = jnp.dot(q, k.T, preferred_element_type=jnp.float32)   # (c, c)
    d = F - F.T                               # F_i - F_j
    mask = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.exp(jnp.where(mask, d, -1e30))
    scores = qk * decay
    y_intra = jnp.dot(scores, v, preferred_element_type=jnp.float32)
    n_intra = scores.sum(-1, keepdims=True)   # (c, 1)

    k_tail = k * jnp.exp(total - F)           # (c, dk)
    state_ref[...] = (jnp.exp(total) * S_prev
                      + jnp.dot(k_tail.T, v,
                                preferred_element_type=jnp.float32))
    norm_ref[...] = (jnp.exp(total) * n_prev
                     + k_tail.sum(0, keepdims=True).T)

    y = y_inter + y_intra
    if normalize:
        y = y / jnp.maximum(jnp.abs(n_inter + n_intra), 1.0)
    y_ref[0] = y.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "normalize",
                                             "interpret"))
def gla(q: jax.Array, k: jax.Array, v: jax.Array, log_a: jax.Array, *,
        chunk: int = 128, normalize: bool = True,
        interpret: bool = False) -> jax.Array:
    """q/k (B, S, H, dk), v (B, S, H, dv), log_a (B, S, H) -> y (B,S,H,dv).

    S must be divisible by `chunk`.
    """
    b, s, h, dk = q.shape
    dv = v.shape[-1]
    if s % chunk:
        raise ValueError(f"seq {s} % chunk {chunk} != 0")
    nc = s // chunk

    def flat(x):
        return x.transpose(0, 2, 1, 3).reshape(b * h, s, x.shape[-1])

    qf, kf, vf = flat(q), flat(k), flat(v)
    laf = log_a.transpose(0, 2, 1).reshape(b * h, s, 1)

    out = pl.pallas_call(
        functools.partial(_gla_kernel, nc=nc, chunk=chunk,
                          normalize=normalize),
        grid=(b * h, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, dk), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, chunk, dk), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, chunk, dv), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, chunk, 1), lambda i, c: (i, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, dv), lambda i, c: (i, c, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, dv), v.dtype),
        scratch_shapes=[
            pltpu.VMEM((dk, dv), jnp.float32),   # recurrent state
            pltpu.VMEM((dk, 1), jnp.float32),    # normaliser
        ],
        interpret=interpret,
    )(qf, kf, vf, laf)
    return out.reshape(b, h, s, dv).transpose(0, 2, 1, 3)
