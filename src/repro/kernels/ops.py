"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute in ``interpret=True`` mode — the
kernel body runs as traced Python, validating the exact TPU tiling logic; on
a TPU backend the same calls compile to Mosaic.  ``use_pallas()`` is the
single switch the rest of the framework consults.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import matmul as _mm
from . import flash_attention as _fa
from . import gla as _gla
from . import ref


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def matmul(a, b, *, block_m: int = 128, block_n: int = 128,
           block_k: int = 128, interpret: bool | None = None):
    interpret = _interpret_default() if interpret is None else interpret
    return _mm.matmul(a, b, block_m=block_m, block_n=block_n,
                      block_k=block_k, interpret=interpret)


def addmul(c, a, b, *, block_m: int = 128, block_n: int = 128,
           block_k: int = 128, interpret: bool | None = None):
    interpret = _interpret_default() if interpret is None else interpret
    return _mm.addmul(c, a, b, block_m=block_m, block_n=block_n,
                      block_k=block_k, interpret=interpret)


@functools.lru_cache(maxsize=128)
def _addmul_batched_fn(block_m: int, block_n: int, block_k: int,
                       interpret: bool):
    """One jitted ``vmap`` of the Pallas addmul per block/backend signature.

    The wave executor calls this once per ``(tile shape, dtype)`` group;
    jax's jit cache then specialises per stacked operand shape, so repeated
    waves of the same group signature reuse the compiled executable.
    """
    fn = functools.partial(_mm.addmul, block_m=block_m, block_n=block_n,
                           block_k=block_k, interpret=interpret)
    return jax.jit(jax.vmap(fn))


def addmul_batched(c, a, b, *, block_m: int = 128, block_n: int = 128,
                   block_k: int = 128, interpret: bool | None = None):
    """Stacked GEMM-accumulate: ``out[i] = c[i] + a[i] @ b[i]``.

    ``jax.vmap`` over the blocked Pallas kernel — the wave-batched
    executor's ADDMUL group call (one launch per group instead of one per
    tile task).
    """
    interpret = _interpret_default() if interpret is None else interpret
    fn = _addmul_batched_fn(block_m, block_n, block_k, interpret)
    return fn(jnp.asarray(c), jnp.asarray(a), jnp.asarray(b))


def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool | None = None):
    interpret = _interpret_default() if interpret is None else interpret
    return _fa.flash_attention(q, k, v, causal=causal, block_q=block_q,
                               block_k=block_k, interpret=interpret)


def gla(q, k, v, log_a, *, chunk: int = 128, normalize: bool = True,
        interpret: bool | None = None):
    interpret = _interpret_default() if interpret is None else interpret
    return _gla.gla(q, k, v, log_a, chunk=chunk, normalize=normalize,
                    interpret=interpret)


__all__ = ["matmul", "addmul", "addmul_batched", "flash_attention", "gla",
           "ref"]
