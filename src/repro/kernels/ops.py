"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute in ``interpret=True`` mode — the
kernel body runs as traced Python, validating the exact TPU tiling logic; on
a TPU backend the same calls compile to Mosaic.  ``use_pallas()`` is the
single switch the rest of the framework consults.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import matmul as _mm
from . import flash_attention as _fa
from . import gla as _gla
from . import ref


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _resolve_blocks(block_m, block_n, block_k, m, n, k):
    """Autotuned-by-shape block defaults: clamp to the actual tile dims.

    A 16x16 CMM tile must not be padded out to 128-blocks — at tile sizes
    below the MXU-aligned default the padding would dominate the launch
    (64x the FLOPs for a 16x16 tile).  Explicitly passed block sizes are
    honoured as-is (the core/autotune.py candidates loop sets them).
    """
    if block_m is None:
        block_m = min(128, m)
    if block_n is None:
        block_n = min(128, n)
    if block_k is None:
        block_k = min(128, k)
    return block_m, block_n, block_k


def matmul(a, b, *, block_m: int | None = None, block_n: int | None = None,
           block_k: int | None = None, interpret: bool | None = None):
    interpret = _interpret_default() if interpret is None else interpret
    block_m, block_n, block_k = _resolve_blocks(
        block_m, block_n, block_k, a.shape[0], b.shape[1], a.shape[1])
    return _mm.matmul(a, b, block_m=block_m, block_n=block_n,
                      block_k=block_k, interpret=interpret)


def addmul(c, a, b, *, block_m: int | None = None, block_n: int | None = None,
           block_k: int | None = None, interpret: bool | None = None,
           epilogue=None, extras=(), out_dtype=None):
    """GEMM-accumulate ``c + a @ b``; with ``epilogue`` a FUSED tile
    program, the elementwise chain is fused into the same kernel launch
    (applied to the f32 accumulator before the store)."""
    interpret = _interpret_default() if interpret is None else interpret
    block_m, block_n, block_k = _resolve_blocks(
        block_m, block_n, block_k, a.shape[0], b.shape[1], a.shape[1])
    if epilogue is None:
        return _mm.addmul(c, a, b, block_m=block_m, block_n=block_n,
                          block_k=block_k, interpret=interpret)
    return _mm.addmul_epilogue(
        c, a, b, *extras, prog=tuple(epilogue), block_m=block_m,
        block_n=block_n, block_k=block_k, out_dtype=out_dtype,
        interpret=interpret)


@functools.lru_cache(maxsize=128)
def _addmul_batched_fn(block_m: int, block_n: int, block_k: int,
                       interpret: bool, prog=None, nextra: int = 0,
                       out_dtype=None):
    """One jitted ``vmap`` of the Pallas addmul per block/backend signature.

    The wave executor calls this once per ``(tile shape, dtype)`` group;
    jax's jit cache then specialises per stacked operand shape, so repeated
    waves of the same group signature reuse the compiled executable.
    Epilogued groups key additionally on (program, extra count, store
    dtype) — each distinct fused chain is its own executable.
    """
    if prog is None:
        fn = functools.partial(_mm.addmul, block_m=block_m, block_n=block_n,
                               block_k=block_k, interpret=interpret)
    else:
        def fn(c, a, b, *extras):
            return _mm.addmul_epilogue(
                c, a, b, *extras, prog=prog, block_m=block_m,
                block_n=block_n, block_k=block_k, out_dtype=out_dtype,
                interpret=interpret)
    return jax.jit(jax.vmap(fn))


def addmul_batched(c, a, b, *, block_m: int | None = None,
                   block_n: int | None = None, block_k: int | None = None,
                   interpret: bool | None = None,
                   epilogue=None, extras=(), out_dtype=None):
    """Stacked GEMM-accumulate: ``out[i] = c[i] + a[i] @ b[i]``.

    ``jax.vmap`` over the blocked Pallas kernel — the wave-batched
    executor's ADDMUL group call (one launch per group instead of one per
    tile task).  With ``epilogue``, the group's fused elementwise chain
    runs inside the same launch (``extras`` are the stacked chain
    operands beyond the accumulator; ``out_dtype`` is the mixed-precision
    store override).
    """
    interpret = _interpret_default() if interpret is None else interpret
    block_m, block_n, block_k = _resolve_blocks(
        block_m, block_n, block_k, a.shape[1], b.shape[2], a.shape[2])
    if out_dtype is not None:
        out_dtype = np.dtype(out_dtype)
    fn = _addmul_batched_fn(
        block_m, block_n, block_k, interpret,
        prog=None if epilogue is None else tuple(epilogue),
        nextra=len(extras), out_dtype=out_dtype)
    return fn(jnp.asarray(c), jnp.asarray(a), jnp.asarray(b),
              *[jnp.asarray(e) for e in extras])


def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool | None = None):
    interpret = _interpret_default() if interpret is None else interpret
    return _fa.flash_attention(q, k, v, causal=causal, block_q=block_q,
                               block_k=block_k, interpret=interpret)


def gla(q, k, v, log_a, *, chunk: int = 128, normalize: bool = True,
        interpret: bool | None = None):
    interpret = _interpret_default() if interpret is None else interpret
    return _gla.gla(q, k, v, log_a, chunk=chunk, normalize=normalize,
                    interpret=interpret)


__all__ = ["matmul", "addmul", "addmul_batched", "flash_attention", "gla",
           "ref"]
