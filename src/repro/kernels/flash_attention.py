"""Pallas flash attention (forward) — blocked online-softmax attention.

Used by the LM stack's prefill path on TPU.  Tiling: grid over
(batch*heads, q_blocks, kv_blocks) with the kv dimension minor-most; per
(bh, qi) the kernel keeps the running max ``m``, normaliser ``l`` and the
fp32 output accumulator in VMEM scratch, so the S x S score matrix never
exists in HBM — the standard flash schedule re-blocked for VMEM (the MXU
consumes (block_q, d) x (d, block_k) score GEMMs).

Causal masking is two-level: kv blocks strictly above the diagonal are
skipped entirely (``pl.when`` — no MXU work is issued), and the diagonal
block is masked elementwise with iotas.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
               nkv: int, scale: float, causal: bool,
               block_q: int, block_k: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # causal: skip kv blocks entirely above the diagonal
    run = (ki * block_k <= (qi + 1) * block_q - 1) if causal else True

    @pl.when(run)
    def _block():
        q = q_ref[0]                      # (block_q, d)
        k = k_ref[0]                      # (block_k, d)
        v = v_ref[0]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        if causal:
            rows = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            cols = ki * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        m_prev = m_ref[...]               # (block_q, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == nkv - 1)
    def _done():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "block_q", "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, block_q: int = 128,
                    block_k: int = 128,
                    interpret: bool = False) -> jax.Array:
    """(B, H, S, D) flash attention; S must be padded to block multiples by
    the caller for the causal case (non-causal pads with masked keys)."""
    b, h, s, d = q.shape
    sk = k.shape[2]
    if s % block_q or sk % block_k:
        raise ValueError(f"seq {s}/{sk} not divisible by blocks "
                         f"{block_q}/{block_k}")
    scale = 1.0 / (d ** 0.5)
    qf = q.reshape(b * h, s, d)
    kf = k.reshape(b * h, sk, d)
    vf = v.reshape(b * h, sk, d)
    grid = (b * h, s // block_q, sk // block_k)
    out = pl.pallas_call(
        functools.partial(_fa_kernel, nkv=grid[2], scale=scale,
                          causal=causal, block_q=block_q, block_k=block_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, i, j: (bh, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, i, j: (bh, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, i, j: (bh, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda bh, i, j: (bh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max m
            pltpu.VMEM((block_q, 1), jnp.float32),   # normaliser l
            pltpu.VMEM((block_q, d), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, s, d)
