"""nemotron-4-340b — dense decoder, squared-ReLU MLP, GQA kv=8
[arXiv:2402.16819].

At 340B params on a 256-chip v5e pod, AdamW fp32 moments alone exceed HBM;
the plan uses AdaFactor (factored second moment) — see EXPERIMENTS.md
§Dry-run memory notes.
"""
from .base import ModelConfig, ParallelPlan

CONFIG = ModelConfig(
    name="nemotron-4-340b", family="dense",
    n_layers=96, d_model=18432, n_heads=96, n_kv=8, d_ff=73728,
    vocab=256000, act="squared_relu", rope_theta=1e4,
    source="arXiv:2402.16819",
)


def reduced() -> ModelConfig:
    from dataclasses import replace
    return replace(CONFIG, n_layers=2, d_model=96, n_heads=6, n_kv=2,
                   d_ff=384, vocab=512)


PLAN_OVERRIDES = {
    "default": ParallelPlan(microbatches=4, optimizer="adafactor"),
    "train_4k": ParallelPlan(microbatches=16, optimizer="adafactor",
                             grad_reduce="psum_scatter"),
}
