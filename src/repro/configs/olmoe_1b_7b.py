"""olmoe-1b-7b — 64-expert top-8 MoE [arXiv:2409.02060]."""
from .base import ModelConfig, ParallelPlan

CONFIG = ModelConfig(
    name="olmoe-1b-7b", family="moe",
    n_layers=16, d_model=2048, n_heads=16, n_kv=16, d_ff=1024,
    vocab=50304, act="silu", qk_norm=True, rope_theta=1e4,
    n_experts=64, top_k=8,
    source="arXiv:2409.02060",
)


def reduced() -> ModelConfig:
    from dataclasses import replace
    return replace(CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv=4,
                   d_ff=32, vocab=512, n_experts=8, top_k=2)


PLAN_OVERRIDES = {
    "default": ParallelPlan(microbatches=2, moe_impl="expert_parallel"),
    "train_4k": ParallelPlan(microbatches=4, moe_impl="expert_parallel"),
}
