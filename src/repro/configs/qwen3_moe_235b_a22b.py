"""qwen3-moe-235b-a22b — 128-expert top-8 MoE [hf:Qwen/Qwen3 family].

d_ff=1536 is the PER-EXPERT width (MoE convention in base.ModelConfig).
Experts shard over the 16-way `model` axis (8 experts/device); the dispatch
einsum lowers to all-to-all.
"""
from .base import ModelConfig, ParallelPlan

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv=4, d_ff=1536,
    d_head=128, vocab=151936, act="silu", qk_norm=True, rope_theta=1e6,
    n_experts=128, top_k=8,
    source="hf:Qwen/Qwen3-30B-A3B (scaled per assignment)",
)


def reduced() -> ModelConfig:
    from dataclasses import replace
    return replace(CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv=2,
                   d_head=16, d_ff=32, vocab=512, n_experts=8, top_k=2)


PLAN_OVERRIDES = {
    # shard_map expert parallelism (§Perf cell B: 3.0x step-bound win)
    "default": ParallelPlan(microbatches=4, moe_impl="expert_parallel"),
    "train_4k": ParallelPlan(microbatches=16, moe_impl="expert_parallel"),
}
