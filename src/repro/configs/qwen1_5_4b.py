"""qwen1.5-4b — dense decoder, QKV bias [hf:Qwen/Qwen1.5 family]."""
from .base import ModelConfig, ParallelPlan

CONFIG = ModelConfig(
    name="qwen1.5-4b", family="dense",
    n_layers=40, d_model=2560, n_heads=20, n_kv=20, d_ff=6912,
    vocab=151936, act="silu", qkv_bias=True, rope_theta=1e6,
    source="hf:Qwen/Qwen1.5-0.5B (scaled per assignment)",
)


def reduced() -> ModelConfig:
    from dataclasses import replace
    return replace(CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv=4,
                   d_ff=160, vocab=512)


PLAN_OVERRIDES = {
    # indivisible heads (20 on 16) -> context parallelism (§Perf cell A)
    "default": ParallelPlan(microbatches=2).with_rules(
        seq_attn=("model",), seq_act=("model",)),
    "train_4k": ParallelPlan(microbatches=8, gather_once=True).with_rules(
        seq_attn=("model",), seq_act=("model",)),
}
