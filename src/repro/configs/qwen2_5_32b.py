"""qwen2.5-32b — dense decoder, GQA kv=8, QKV bias [hf:Qwen/Qwen2.5]."""
from .base import ModelConfig, ParallelPlan

CONFIG = ModelConfig(
    name="qwen2.5-32b", family="dense",
    n_layers=64, d_model=5120, n_heads=40, n_kv=8, d_ff=27648,
    vocab=152064, act="silu", qkv_bias=True, rope_theta=1e6,
    source="hf:Qwen/Qwen2.5-0.5B (scaled per assignment)",
)


def reduced() -> ModelConfig:
    from dataclasses import replace
    return replace(CONFIG, n_layers=2, d_model=80, n_heads=5, n_kv=1,
                   d_ff=224, vocab=512)


PLAN_OVERRIDES = {
    # indivisible heads (20 on 16) -> context parallelism (§Perf cell A)
    "default": ParallelPlan(microbatches=2).with_rules(
        seq_attn=("model",), seq_act=("model",)),
    "train_4k": ParallelPlan(microbatches=8, gather_once=True).with_rules(
        seq_attn=("model",), seq_act=("model",)),
}
