"""xlstm-1.3b — mLSTM matrix-memory blocks [arXiv:2405.04517].

The 1.3B config uses the mLSTM-dominant xLSTM[1:0] layout (all-mLSTM) so the
layer stack scans uniformly; the sLSTM cell is implemented and unit-tested
(``slstm_every`` mixes it in for tests).  d_ff=0 per assignment: the mLSTM
block is the whole sublayer (2x up-projection, per-head gates, down-proj).
Recurrent state means long_500k decode is O(1) in sequence length.
"""
from .base import ModelConfig, ParallelPlan

CONFIG = ModelConfig(
    name="xlstm-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=4, n_kv=4, d_ff=0,
    vocab=50304, block="mlstm", pos="none",
    source="arXiv:2405.04517",
)


def reduced() -> ModelConfig:
    from dataclasses import replace
    return replace(CONFIG, n_layers=2, d_model=64, n_heads=2, n_kv=2,
                   vocab=512)


PLAN_OVERRIDES = {
    # 4 heads don't divide 16: shard the mLSTM value head-dim instead
    "default": ParallelPlan(microbatches=2).with_rules(head_dv=("model",)),
    "train_4k": ParallelPlan(microbatches=8).with_rules(head_dv=("model",)),
}
