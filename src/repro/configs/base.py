"""Config system: architectures, input-shape cells, and parallelism plans.

``ModelConfig`` describes an architecture; ``ShapeCell`` one assigned input
shape; ``ParallelPlan`` a sharding/microbatching layout.  The dry-run
enumerates (arch x shape x mesh); the CMM-style autotuner picks plans by
predicted cost (core/autotune.py + launch/roofline.py).
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    d_head: int = 0             # 0 -> d_model // n_heads
    act: str = "silu"
    qkv_bias: bool = False
    qk_norm: bool = False
    norm: str = "rmsnorm"       # rmsnorm | layernorm
    pos: str = "rope"           # rope | sinusoidal
    rope_theta: float = 1e6
    tie_embeddings: bool = False
    # encoder-decoder (whisper)
    enc_dec: bool = False
    enc_layers: int = 0
    enc_frames: int = 1500      # audio stub sequence length
    # vlm (phi-3-vision)
    vision_patches: int = 0     # patch-embedding stub tokens prepended
    # moe
    n_experts: int = 0
    top_k: int = 0
    moe_capacity: float = 1.25
    # ssm / hybrid
    block: str = "attn"         # attn | mlstm | hymba
    ssm_state: int = 0          # GLA key dim for mamba-style heads
    window: int = 0             # sliding-window size for hybrid attention
    slstm_every: int = 0        # xLSTM: optional sLSTM block cadence (tests)
    # numerics
    dtype: str = "bfloat16"
    source: str = ""            # provenance note

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def vocab_padded(self, mult: int = 16) -> int:
        return -(-self.vocab // mult) * mult

    # -- parameter accounting (for 6ND MODEL_FLOPS) ------------------------
    def param_counts(self) -> Dict[str, int]:
        d, hd = self.d_model, self.head_dim
        h, kv, ff = self.n_heads, self.n_kv, self.d_ff
        attn = d * h * hd + 2 * d * kv * hd + h * hd * d
        if self.block == "mlstm":
            # q/k/v/out + gates (see lm.py mlstm block)
            per_layer = d * (2 * d) * 2 + 2 * d * (2 * d) + 2 * d * 2 * self.n_heads
            per_layer += 2 * d
        elif self.block == "hymba":
            glah = self.n_heads
            ssm = d * glah * self.ssm_state * 2 + d * glah * hd + glah * hd * d \
                + d * glah
            mlp = 3 * d * ff
            per_layer = attn + ssm + mlp + 4 * d
        elif self.is_moe:
            ffe = ff  # for MoE archs d_ff is the per-expert width
            moe = d * self.n_experts + self.n_experts * 3 * d * ffe
            per_layer = attn + moe + 2 * d
        else:
            mlp = (3 if self.act == "silu" else 2) * d * ff
            per_layer = attn + mlp + 2 * d
        total = self.n_layers * per_layer
        if self.enc_dec:
            enc = self.enc_layers * (attn + 2 * d * ff + 2 * d)
            dec_cross = self.n_layers * (attn + d)
            total += enc + dec_cross
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        total += emb + d
        if self.is_moe:
            ffe = ff
            active_moe = d * self.n_experts + self.top_k * 3 * d * ffe
            mlp_full = self.n_experts * 3 * d * ffe + d * self.n_experts
            active = total - self.n_layers * (mlp_full - active_moe)
        else:
            active = total
        return {"total": int(total), "active": int(active)}


@dataclass(frozen=True)
class ShapeCell:
    name: str                   # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                   # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES: Dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}

#: archs whose attention is sub-quadratic / recurrent -> run long_500k
LONG_CONTEXT_OK = {"xlstm-1.3b", "hymba-1.5b"}


@dataclass(frozen=True)
class ParallelPlan:
    """Sharding layout: logical-axis -> mesh-axis rules + step options.

    Rules may name mesh axes that do not exist on a given mesh (e.g. 'pod'
    on the single-pod mesh) — they are dropped at resolution time.  A rule
    whose target does not evenly divide the dimension is dropped too (e.g.
    20 heads on a 16-way 'model' axis), with the drop recorded.
    """

    name: str = "fsdp_tp"
    rules: Tuple[Tuple[str, Tuple[str, ...]], ...] = (
        ("batch", ("pod", "data")),
        ("embed", ("data",)),       # FSDP (ZeRO-3) storage shard
        ("heads", ("model",)),
        ("kv_heads", ("model",)),
        ("head_dv", ()),            # xlstm: shard value head dim instead
        ("ff", ("model",)),
        ("vocab", ("model",)),
        ("experts", ("model",)),
        ("expert_ff", ()),
        ("seq", ()),
        ("seq_kv", ("model",)),   # decode KV-cache sequence sharding
        ("seq_attn", ()),         # context parallelism: q-sequence on model
        ("seq_act", ()),          # Megatron-SP: activations' seq on model
        ("state", ()),
        ("frames", ()),
    )
    microbatches: int = 1
    remat: bool = True
    #: kv cache layout for decode: auto | heads | seq | replicated
    kv_shard: str = "auto"
    #: gradient cross-replica reduction: psum | psum_scatter
    grad_reduce: str = "psum"
    #: optimizer: adamw | adafactor
    optimizer: str = "adamw"
    #: int8 gradient compression for the DP all-reduce
    compress_grads: bool = False
    #: KV-chunk size for the flash attention scan
    attn_chunk: int = 1024
    #: explicit sharding constraints on MoE dispatch/expert tensors
    moe_constraints: bool = False
    #: MoE execution: scatter (GSPMD) | expert_parallel (shard_map)
    moe_impl: str = "scatter"
    #: constrain accumulated grads to param sharding inside the micro loop
    #: (forces reduce-scatter placement instead of all-reduce + slice)
    grad_constraint: bool = False
    #: all-gather FSDP-sharded weights ONCE per step (outside the microbatch
    #: scan) and reuse across microbatches — the CMM node-level-cache insight;
    #: costs model-sharded-only weight residency (fits when params/16 < HBM)
    gather_once: bool = False

    def rule(self, logical: str) -> Tuple[str, ...]:
        for k, v in self.rules:
            if k == logical:
                return v
        return ()

    def with_rules(self, **updates) -> "ParallelPlan":
        rules = tuple((k, tuple(updates.pop(k)) if k in updates else v)
                      for k, v in self.rules)
        if updates:
            raise ValueError(f"unknown logical axes: {sorted(updates)}")
        return replace(self, rules=rules)


#: registry of assigned architectures
ARCH_IDS: List[str] = [
    "whisper-large-v3", "qwen1.5-4b", "qwen3-8b", "qwen2.5-32b",
    "nemotron-4-340b", "phi-3-vision-4.2b", "xlstm-1.3b", "hymba-1.5b",
    "qwen3-moe-235b-a22b", "olmoe-1b-7b",
]

_MODULES = {
    "whisper-large-v3": "whisper_large_v3",
    "qwen1.5-4b": "qwen1_5_4b",
    "qwen3-8b": "qwen3_8b",
    "qwen2.5-32b": "qwen2_5_32b",
    "nemotron-4-340b": "nemotron_4_340b",
    "phi-3-vision-4.2b": "phi_3_vision_4_2b",
    "xlstm-1.3b": "xlstm_1_3b",
    "hymba-1.5b": "hymba_1_5b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "olmoe-1b-7b": "olmoe_1b_7b",
}


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.CONFIG


def get_reduced(arch: str) -> ModelConfig:
    """Smoke-test variant: same family/topology, tiny dims."""
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.reduced()


def get_plan(arch: str, shape: str) -> ParallelPlan:
    """Per-(arch, shape) tuned plan; configs may override `plan_overrides`."""
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    over = getattr(mod, "PLAN_OVERRIDES", {})
    if shape in over:
        return over[shape]
    return over.get("default", ParallelPlan())


def cells(arch: str) -> List[str]:
    """Shape cells that apply to this arch (long_500k gating)."""
    out = []
    for s in SHAPES:
        if s == "long_500k" and arch not in LONG_CONTEXT_OK:
            continue
        out.append(s)
    return out
