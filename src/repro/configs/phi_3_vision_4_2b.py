"""phi-3-vision-4.2b — phi3-mini backbone + CLIP stub
[hf:microsoft/Phi-3-vision-128k-instruct].

The CLIP tower is a STUB: ``input_specs()`` provides precomputed
(B, 576, d_model) patch embeddings, prepended to the token sequence
(loss-masked).
"""
from .base import ModelConfig, ParallelPlan

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b", family="vlm",
    n_layers=32, d_model=3072, n_heads=32, n_kv=32, d_ff=8192,
    vocab=32064, act="silu", rope_theta=1e4, vision_patches=576,
    source="hf:microsoft/Phi-3-vision-128k-instruct",
)


def reduced() -> ModelConfig:
    from dataclasses import replace
    return replace(CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv=4,
                   d_ff=160, vocab=512, vision_patches=8)


PLAN_OVERRIDES = {
    "default": ParallelPlan(microbatches=2),
    "train_4k": ParallelPlan(microbatches=8),
}
