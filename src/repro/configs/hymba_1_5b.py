"""hymba-1.5b — parallel attention + mamba heads per block
[arXiv:2411.13676].

Each block runs sliding-window GQA attention and mamba-style GLA heads in
parallel on the same input; branch outputs are per-branch normalised and
averaged (the paper's fusion), then an MLP sublayer follows.  Sliding
window + SSM state keeps decode sub-quadratic -> long_500k runs.
"""
from .base import ModelConfig, ParallelPlan

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv=5, d_ff=5504,
    vocab=32001, block="hymba", ssm_state=16, window=1024,
    rope_theta=1e4,
    source="arXiv:2411.13676",
)


def reduced() -> ModelConfig:
    from dataclasses import replace
    return replace(CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv=2,
                   d_ff=160, vocab=512, window=16)


PLAN_OVERRIDES = {
    # 25 heads don't divide 16 -> heads rule auto-drops; ff/vocab TP only
    "default": ParallelPlan(microbatches=2),
    "train_4k": ParallelPlan(microbatches=8),
}
