"""qwen3-8b — dense decoder, GQA kv=8, qk-norm [hf:Qwen/Qwen3-8B]."""
from .base import ModelConfig, ParallelPlan

CONFIG = ModelConfig(
    name="qwen3-8b", family="dense",
    n_layers=36, d_model=4096, n_heads=32, n_kv=8, d_ff=12288,
    d_head=128, vocab=151936, act="silu", qk_norm=True, rope_theta=1e6,
    source="hf:Qwen/Qwen3-8B",
)


def reduced() -> ModelConfig:
    from dataclasses import replace
    return replace(CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv=2,
                   d_head=16, d_ff=192, vocab=512)


PLAN_OVERRIDES = {
    "default": ParallelPlan(microbatches=2),
    "train_4k": ParallelPlan(microbatches=8),
}
