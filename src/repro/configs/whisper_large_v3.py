"""whisper-large-v3 — encoder-decoder audio backbone [arXiv:2212.04356].

The conv/mel frontend is a STUB: ``input_specs()`` feeds precomputed
(B, 1500, d_model) frame embeddings to the encoder.
"""
from .base import ModelConfig, ParallelPlan

CONFIG = ModelConfig(
    name="whisper-large-v3", family="audio",
    n_layers=32, d_model=1280, n_heads=20, n_kv=20, d_ff=5120,
    vocab=51866, act="gelu", norm="layernorm", pos="sinusoidal",
    qkv_bias=True, enc_dec=True, enc_layers=32, enc_frames=1500,
    source="arXiv:2212.04356",
)


def reduced() -> ModelConfig:
    from dataclasses import replace
    return replace(CONFIG, n_layers=2, enc_layers=2, d_model=64, n_heads=4,
                   n_kv=4, d_ff=128, vocab=512, enc_frames=16)


PLAN_OVERRIDES = {
    # 20 heads don't divide the 16-way model axis -> context parallelism:
    # q-sequence + activation seq shard over `model` (see §Perf cell A).
    "default": ParallelPlan(microbatches=4).with_rules(
        seq_attn=("model",), seq_act=("model",)),
    "train_4k": ParallelPlan(microbatches=8).with_rules(
        seq_attn=("model",), seq_act=("model",)),
}
